"""Paper Table 1: throughput (frames/sec) of coupled vs decoupled
pipelines on two tasks — 'catch' (cheap, fixed-length; task-1 analogue)
and 'chase' (variable-length episodes; task-2 analogue).

Variants mirror Figure 2:
  a2c_sync_step   act 1 step, learn nothing until batch step done, policy
                  applied per env step in lockstep with learning barrier
  a2c_sync_traj   unroll n steps with the CURRENT params, learn, repeat
                  (batched A2C, sync trajectories)
  impala          unroll with STALE params (queue + lag) so acting is
                  decoupled from the learner's update cycle — but still
                  one thread (simulated decoupling)
  impala_async    the real thing (repro.distributed): actor threads
                  overlap the learner, which drains the queue with
                  dynamic batching; fps counts learner-consumed frames
                  at steady state
  impala_proc     actor *processes* over the serialized shm transport —
                  acting leaves the learner's interpreter entirely, the
                  trajectory pipeline crosses a real byte boundary
  impala_socket   actor processes dialing the learner over TCP loopback
                  (the cross-machine deployment shape, on one box):
                  CRC-framed trajectories up, versioned params down
  impala_socket_bf16  the same socket deployment with the bf16 wire
                  codec: trajectory observations and published params
                  quantized on the wire; tracked next to impala_socket
                  (fps + bytes/frame + mean lag) so the bandwidth diet
                  is measured, not assumed
  impala_infserve       thread actors in *inference mode*: host-side env
                  stepping against the dynamic-batching
                  InferenceService (one batched policy forward on the
                  learner's device, §3.1), zero per-actor params
  impala_infserve_proc  the same service fed by actor processes: serde
                  observation/action frames over the service wire
  impala_replay   impala_async with a 0.5 replay top-up: the learner
                  caps fresh collection at half the batch and fills the
                  rest from the prioritized trajectory replay (reuse
                  K=2, target-baseline V-trace); fps counts frames the
                  optimizer TRAINED on, and the JSON's "replay" section
                  records the per-env-step training multiplier
  impala_2learner two learner *processes* (a LearnerGroup), the actor
                  slots sharded between them, gradients mean-reduced
                  over the framed channel every round; fps counts the
                  group's summed learner-consumed frames. On a 2-core
                  box the two jitted train steps contend for the same
                  cores the actors need (like impala_proc, the win
                  needs cores); the variant is tracked so the scaling
                  is measured, not assumed
  impala_spmd     the SPMD learner (--learner-mode spmd) on a forced
                  2-device CPU host at the same global batch as
                  impala_2learner (one learner, max_batch_trajs 8
                  sharded 4+4 vs two learners x 4 — same per-worker
                  math, no TCP): the train step is a shard_map over a
                  ('data',) mesh, gradients mean-reduced by an in-XLA
                  psum — zero TCP frames in the gradient path (the
                  JSON's "spmd" section pins exchange_backend and the
                  absence of wire byte counters). Runs in a child
                  process because forcing the device count only works
                  before the first jax import

Besides the CSV rows, the run writes ``BENCH_throughput.json`` (variant
-> frames/sec plus run metadata) so the perf trajectory is tracked
across PRs instead of only printed. ``BENCH_ENVS`` (comma-separated)
restricts the env set — the CI smoke job runs catch only.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, emit, small_arch
from repro.configs.base import ImpalaConfig
from repro.core import actor as actor_lib
from repro.core import learner as learner_lib
from repro.core.queue import LagController
from repro.data.envs import make_env
from repro.models import backbone as bb
from repro.models import common as pcommon


def _measure(env_name: str, variant: str, num_envs: int = 32,
             unroll: int = 20, iters: int = 20) -> float:
    env = make_env(env_name)
    arch = small_arch(env)
    icfg = ImpalaConfig(num_actions=env.num_actions,
                        unroll_length=1 if variant == "a2c_sync_step"
                        else unroll,
                        policy_lag=0 if variant.startswith("a2c") else 2)
    specs = bb.backbone_specs(arch, env.num_actions)
    params = pcommon.init_params(specs, jax.random.key(0))
    init_fn, unroll_fn = actor_lib.build_actor(env, arch, icfg, num_envs)
    train_step, opt = learner_lib.build_train_step(arch, icfg,
                                                   env.num_actions)
    train_step = jax.jit(train_step)
    opt_state = opt.init(params)
    carry = init_fn(jax.random.key(1))
    lag = LagController(icfg.policy_lag, params)

    steps_per_iter = unroll if variant == "a2c_sync_step" else 1
    # warmup/compile
    carry, traj = unroll_fn(lag.actor_params(), carry)
    params, opt_state, _ = train_step(params, opt_state, jnp.int32(0), traj)
    jax.block_until_ready(params)

    frames = 0
    t0 = time.perf_counter()
    for it in range(iters):
        for _ in range(steps_per_iter):
            carry, traj = unroll_fn(lag.actor_params(), carry)
            params, opt_state, _ = train_step(params, opt_state,
                                              jnp.int32(it), traj)
            lag.on_update(params)
            frames += num_envs * icfg.unroll_length
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    return frames / dt


def _measure_async(env_name: str, num_envs: int = 32, unroll: int = 20,
                   iters: int = 20, num_actors: int = 2,
                   actor_backend: str = "thread",
                   transport: str = "inproc",
                   actor_mode: str = "unroll",
                   wire_codec: str = "none",
                   replay_fraction: float = 0.0) -> dict:
    from repro.distributed import run_async_training

    env = make_env(env_name)
    icfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=unroll,
                        replay_fraction=replay_fraction)
    _, _, tel = run_async_training(
        env_name, icfg, num_envs, iters, num_actors=num_actors,
        actor_backend=actor_backend, actor_mode=actor_mode,
        transport=transport, wire_codec=wire_codec,
        queue_capacity=8, queue_policy="block", max_batch_trajs=4,
        seed=0, arch=small_arch(env), warm_buckets=True)
    return tel


def _replay_stats(tel: dict) -> dict:
    """Replay economics for the JSON: env-frame consumption vs frames
    the optimizer trained on. ``fps_per_env_step`` is trained frames
    per consumed env frame per second — the headline "2x fewer env
    frames" quantity (1.0 for one-pass IMPALA)."""
    rp = tel.get("replay", {})
    env_fps = tel.get("frames_per_sec", 0.0)
    trained = rp.get("trained_frames_per_sec", 0.0)
    return {
        "env_fps": round(env_fps, 2),
        "trained_fps": round(trained, 2),
        "reuse_ratio": round(rp.get("reuse_ratio", 0.0), 3),
        "fps_per_env_step": round(trained / env_fps if env_fps else 0.0,
                                  3),
        "sampled": rp.get("sampled", 0),
        "occupancy": rp.get("occupancy", 0),
        "staleness_mean": round(
            rp.get("staleness", {}).get("mean", 0.0), 2),
    }


def _wire_stats(tel: dict) -> dict:
    """Trajectory bytes/frame + mean policy lag for the wire-codec
    comparison rows in the JSON."""
    q = tel.get("queue", {})
    return {
        "bytes_per_frame": round(q.get("bytes_per_frame", 0.0), 2),
        "wire_codec": q.get("wire_codec", "none"),
        "lag_mean": round(tel.get("lag", {}).get("mean", 0.0), 3),
    }


def _measure_group(env_name: str, num_envs: int = 32, unroll: int = 20,
                   iters: int = 20, num_learners: int = 2,
                   num_actors: int = 4) -> float:
    from repro.distributed import run_group_training

    env = make_env(env_name)
    icfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=unroll)
    _, _, tel = run_group_training(
        env_name, icfg, num_envs, iters, num_learners=num_learners,
        num_actors=num_actors, actor_backend="thread",
        queue_capacity=8, queue_policy="block", max_batch_trajs=4,
        seed=0, arch=small_arch(env), warm_buckets=True)
    # the group's throughput is the SUM of per-learner steady-state
    # consumption (merge_telemetry already sums frames_per_sec)
    return tel["frames_per_sec"]


# 2 forced devices mirrors the 2-learner group (4 trajectories per
# shard vs 4 per group member); more forced devices on a CPU box only
# oversubscribe the cores the actors need
_SPMD_DEVICES = 2

_SPMD_CHILD = """
import json, sys
from benchmarks.common import small_arch
from repro.configs.base import ImpalaConfig
from repro.data.envs import make_env
from repro.distributed import run_async_training

env_name, num_envs, unroll, iters, actors, devices, mbt = sys.argv[1:8]
env = make_env(env_name)
icfg = ImpalaConfig(num_actions=env.num_actions,
                    unroll_length=int(unroll))
_, _, tel = run_async_training(
    env_name, icfg, int(num_envs), int(iters),
    num_actors=int(actors), spmd_devices=int(devices),
    queue_capacity=8, queue_policy="block",
    max_batch_trajs=int(mbt), seed=0, arch=small_arch(env),
    warm_buckets=True)
print("SPMD_RESULT " + json.dumps({
    "frames_per_sec": tel["frames_per_sec"],
    "group": tel["group"], "exchange": tel["exchange"]}))
"""


def _measure_spmd(env_name: str, num_envs: int = 32, unroll: int = 20,
                  iters: int = 20, num_actors: int = 4,
                  devices: int = _SPMD_DEVICES,
                  max_batch_trajs: int = 8, trials: int = 2) -> dict:
    """Run the SPMD learner in a child process with a forced N-device
    CPU host (XLA_FLAGS must land before the first jax import, and this
    interpreter's jax is already up) and return its telemetry extract.

    Best-of-``trials``: unlike the in-parent variants this one boots a
    cold interpreter + fresh jit cache per measurement, so a single
    trial is extra exposed to scheduler placement on a shared box
    (observed spread between back-to-back runs exceeded 20%); the max
    over two trials reports what the mode sustains rather than one
    cold-start draw."""
    import subprocess

    child_env = dict(os.environ)
    child_env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + child_env.get("XLA_FLAGS", "")).strip()
    child_env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", child_env.get("PYTHONPATH", "")) if p)
    best = None
    for _ in range(max(1, trials)):
        proc = subprocess.run(
            [sys.executable, "-c", _SPMD_CHILD, env_name, str(num_envs),
             str(unroll), str(iters), str(num_actors), str(devices),
             str(max_batch_trajs)],
            capture_output=True, text=True, timeout=1800, env=child_env)
        if proc.returncode != 0:
            raise RuntimeError(f"spmd bench child failed:\n{proc.stderr}")
        line = [ln for ln in proc.stdout.splitlines()
                if ln.startswith("SPMD_RESULT ")][-1]
        tel = json.loads(line[len("SPMD_RESULT "):])
        ex = tel["exchange"]
        # the headline claim: nothing in the gradient path touched a wire
        assert tel["group"]["exchange_backend"] == "collective", \
            tel["group"]
        assert "bytes_in" not in ex and "bytes_out" not in ex, ex
        if best is None or tel["frames_per_sec"] > best["frames_per_sec"]:
            best = tel
    return best


def _spmd_stats(tel: dict) -> dict:
    """SPMD gradient-path facts for the JSON: backend label, device
    count, per-round latency — and the pinned absence of wire bytes."""
    ex = tel["exchange"]
    return {
        "exchange_backend": tel["group"]["exchange_backend"],
        "devices": ex.get("devices", 0),
        "rounds": ex.get("rounds", 0),
        "round_ms_mean": round(ex.get("round_ms_mean", 0.0), 2),
        "tcp_frames_in_grad_path": 0,
    }


def _write_json(fps_by_env, wire_by_env, replay_by_env,
                spmd_by_env) -> None:
    out = {
        "benchmark": "throughput",
        "unit": "frames_per_sec",
        "meta": {
            "fast_mode": FAST,
            "python": sys.version.split()[0],
            "jax": jax.__version__,
            "platform": platform.platform(),
            "cpu_count": os.cpu_count(),
            # cpu_count is the box, not the budget: containers and
            # taskset pin fewer cores, and every fps in this file
            # scales with the pinned set (guarded: Linux-only API)
            "sched_affinity": (len(os.sched_getaffinity(0))
                               if hasattr(os, "sched_getaffinity")
                               else None),
            "devices": [str(d) for d in jax.devices()],
            # the impala_spmd child forces this many CPU devices via
            # XLA_FLAGS (this parent keeps the unforced pool above)
            "spmd_forced_devices": _SPMD_DEVICES,
        },
        "variants": {f"{env_name}/{variant}": round(v, 2)
                     for env_name, fps in fps_by_env.items()
                     for variant, v in fps.items()},
        # trajectory bytes/frame + mean policy lag for the socket
        # variants, so the wire-codec diet is tracked alongside fps
        "wire": {f"{env_name}/{variant}": stats
                 for env_name, per in wire_by_env.items()
                 for variant, stats in per.items()},
        # replay economics: trained-vs-consumed frame rates and the
        # per-env-step training multiplier (1.0 = one-pass IMPALA)
        "replay": {env_name: stats
                   for env_name, stats in replay_by_env.items()},
        # SPMD gradient path: collective backend label, round latency,
        # and the pinned zero-TCP-frames claim
        "spmd": {env_name: stats
                 for env_name, stats in spmd_by_env.items()},
    }
    path = os.environ.get("BENCH_JSON", "BENCH_throughput.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}", flush=True)


def run() -> None:
    iters = 5 if FAST else 20
    # all async variants at the same actor count so the thread-vs-process
    # (and unroll-vs-inference-service) comparisons are apples to apples
    async_actors = 4
    env_names = tuple(
        e.strip()
        for e in os.environ.get("BENCH_ENVS", "catch,chase").split(",")
        if e.strip())
    fps_by_env = {}
    wire_by_env = {}
    replay_by_env = {}
    spmd_by_env = {}
    for env_name in env_names:
        fps = fps_by_env.setdefault(env_name, {})
        for variant in ("a2c_sync_step", "a2c_sync_traj", "impala"):
            fps[variant] = _measure(env_name, variant, iters=iters)
            emit(f"throughput/{env_name}/{variant}",
                 1e6 / max(fps[variant], 1e-9),
                 f"fps={fps[variant]:.0f}")
        # the async variants need a longer run than the sync ones: their
        # fps is a steady-state window opened only after every worker is
        # past startup (jax import + compile, per process for the proc
        # backend), so short runs measure mostly ramp noise
        async_iters = max(iters * 3, 15)
        fps["impala_async"] = _measure_async(
            env_name, iters=async_iters,
            num_actors=async_actors)["frames_per_sec"]
        emit(f"throughput/{env_name}/impala_async",
             1e6 / max(fps["impala_async"], 1e-9),
             f"fps={fps['impala_async']:.0f}")
        # replay economics: same pipeline as impala_async with a 0.5
        # replay top-up — the reported fps counts frames the optimizer
        # TRAINED on (fresh + replayed); the env-frame diet shows up in
        # the "replay" JSON section's fps_per_env_step multiplier
        tel_rep = _measure_async(
            env_name, iters=async_iters, num_actors=async_actors,
            replay_fraction=0.5)
        fps["impala_replay"] = \
            tel_rep["replay"]["trained_frames_per_sec"]
        replay_by_env[env_name] = _replay_stats(tel_rep)
        emit(f"throughput/{env_name}/impala_replay",
             1e6 / max(fps["impala_replay"], 1e-9),
             f"fps={fps['impala_replay']:.0f}")
        fps["impala_proc"] = _measure_async(
            env_name, iters=async_iters, num_actors=async_actors,
            actor_backend="process", transport="shm")["frames_per_sec"]
        emit(f"throughput/{env_name}/impala_proc",
             1e6 / max(fps["impala_proc"], 1e-9),
             f"fps={fps['impala_proc']:.0f}")
        tel_sock = _measure_async(
            env_name, iters=async_iters, num_actors=async_actors,
            actor_backend="remote", transport="socket")
        fps["impala_socket"] = tel_sock["frames_per_sec"]
        wire_by_env.setdefault(env_name, {})["impala_socket"] = \
            _wire_stats(tel_sock)
        emit(f"throughput/{env_name}/impala_socket",
             1e6 / max(fps["impala_socket"], 1e-9),
             f"fps={fps['impala_socket']:.0f}")
        # the same socket deployment with bf16-quantized wire payloads:
        # the fps should hold (or improve) while trajectory bytes/frame
        # drops >= 1.5x — the bandwidth diet headline number
        tel_bf16 = _measure_async(
            env_name, iters=async_iters, num_actors=async_actors,
            actor_backend="remote", transport="socket", wire_codec="bf16")
        fps["impala_socket_bf16"] = tel_bf16["frames_per_sec"]
        wire_by_env[env_name]["impala_socket_bf16"] = _wire_stats(tel_bf16)
        emit(f"throughput/{env_name}/impala_socket_bf16",
             1e6 / max(fps["impala_socket_bf16"], 1e-9),
             f"fps={fps['impala_socket_bf16']:.0f}")
        fps["impala_infserve"] = _measure_async(
            env_name, iters=async_iters, num_actors=async_actors,
            actor_mode="inference")["frames_per_sec"]
        emit(f"throughput/{env_name}/impala_infserve",
             1e6 / max(fps["impala_infserve"], 1e-9),
             f"fps={fps['impala_infserve']:.0f}")
        fps["impala_infserve_proc"] = _measure_async(
            env_name, iters=async_iters, num_actors=async_actors,
            actor_backend="process", transport="shm",
            actor_mode="inference")["frames_per_sec"]
        emit(f"throughput/{env_name}/impala_infserve_proc",
             1e6 / max(fps["impala_infserve_proc"], 1e-9),
             f"fps={fps['impala_infserve_proc']:.0f}")
        fps["impala_2learner"] = _measure_group(
            env_name, iters=async_iters, num_learners=2,
            num_actors=async_actors)
        emit(f"throughput/{env_name}/impala_2learner",
             1e6 / max(fps["impala_2learner"], 1e-9),
             f"fps={fps['impala_2learner']:.0f}")
        # SPMD learner at the 2-learner group's global batch (one
        # learner, max_batch_trajs 8 vs the group's 2 x 4), forced
        # 4-device CPU child: same update math, no TCP in the loop
        tel_spmd = _measure_spmd(
            env_name, iters=async_iters, num_actors=async_actors,
            max_batch_trajs=8)
        fps["impala_spmd"] = tel_spmd["frames_per_sec"]
        spmd_by_env[env_name] = _spmd_stats(tel_spmd)
        emit(f"throughput/{env_name}/impala_spmd",
             1e6 / max(fps["impala_spmd"], 1e-9),
             f"fps={fps['impala_spmd']:.0f}")
        emit(f"throughput/{env_name}/impala_speedup_vs_sync_step", 0.0,
             f"x{fps['impala'] / max(fps['a2c_sync_step'], 1e-9):.2f}")
        emit(f"throughput/{env_name}/async_speedup_vs_sync_traj", 0.0,
             f"x{fps['impala_async'] / max(fps['a2c_sync_traj'], 1e-9):.2f}")
        emit(f"throughput/{env_name}/proc_speedup_vs_async", 0.0,
             f"x{fps['impala_proc'] / max(fps['impala_async'], 1e-9):.2f}")
        emit(f"throughput/{env_name}/socket_vs_proc", 0.0,
             f"x{fps['impala_socket'] / max(fps['impala_proc'], 1e-9):.2f}")
        w = wire_by_env[env_name]
        bpf_ratio = (w["impala_socket"]["bytes_per_frame"] /
                     max(w["impala_socket_bf16"]["bytes_per_frame"], 1e-9))
        emit(f"throughput/{env_name}/bf16_wire_diet_bytes_per_frame", 0.0,
             f"x{bpf_ratio:.2f} ({w['impala_socket']['bytes_per_frame']:.0f}"
             f" -> {w['impala_socket_bf16']['bytes_per_frame']:.0f} B/frame)")
        emit(f"throughput/{env_name}/infserve_speedup_vs_async", 0.0,
             f"x{fps['impala_infserve'] / max(fps['impala_async'], 1e-9):.2f}")
        emit(f"throughput/{env_name}/group2_vs_proc", 0.0,
             f"x{fps['impala_2learner'] / max(fps['impala_proc'], 1e-9):.2f}")
        emit(f"throughput/{env_name}/spmd_vs_group2", 0.0,
             f"x{fps['impala_spmd'] / max(fps['impala_2learner'], 1e-9):.2f}")
        r = replay_by_env[env_name]
        emit(f"throughput/{env_name}/replay_fps_per_env_step", 0.0,
             f"x{r['fps_per_env_step']:.2f} (reuse={r['reuse_ratio']:.2f},"
             f" env_fps={r['env_fps']:.0f})")
    _write_json(fps_by_env, wire_by_env, replay_by_env, spmd_by_env)
