"""V-trace microbenchmark: scan vs Pallas(interpret) vs O(T^2) reference at
the paper's learner shapes (unroll n=100, batch 32) and at train_4k scale."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import vtrace as vt


def _args(b, t, key=0):
    ks = jax.random.split(jax.random.key(key), 5)
    return (jax.random.normal(ks[0], (b, t)) * 0.3,
            jnp.full((b, t), 0.99),
            jax.random.normal(ks[1], (b, t)),
            jax.random.normal(ks[2], (b, t)),
            jax.random.normal(ks[3], (b,)))


def run() -> None:
    for (b, t, tag) in [(32, 100, "paper_n100_b32"),
                        (256, 1024, "train1k_b256")]:
        args = _args(b, t)
        scan = jax.jit(lambda *a: vt.vtrace_scan(*a).vs)
        us = timeit(lambda: jax.block_until_ready(scan(*args)), n=20)
        emit(f"vtrace/{tag}/scan", us, f"tokens_per_s={b*t/us*1e6:.0f}")
        from repro.kernels import ops
        pal = lambda: jax.block_until_ready(
            ops.vtrace(*args, impl="pallas")[0])
        us_p = timeit(pal, n=3)
        emit(f"vtrace/{tag}/pallas_interpret", us_p,
             "interpret-mode (CPU correctness path, not TPU speed)")
    args = _args(8, 64)
    ref = jax.jit(lambda *a: vt.vtrace_reference(*a).vs)
    us_r = timeit(lambda: jax.block_until_ready(ref(*args)), n=5)
    emit("vtrace/ref_T64_b8/reference_quadratic", us_r, "oracle")
