"""V-trace microbenchmark: scan vs Pallas(interpret) vs O(T^2) reference at
the paper's learner shapes (unroll n=100, batch 32) and at train_4k scale,
plus the fused loss/V-trace kernel against its unfused XLA composition."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import vtrace as vt


def _args(b, t, key=0):
    ks = jax.random.split(jax.random.key(key), 5)
    return (jax.random.normal(ks[0], (b, t)) * 0.3,
            jnp.full((b, t), 0.99),
            jax.random.normal(ks[1], (b, t)),
            jax.random.normal(ks[2], (b, t)),
            jax.random.normal(ks[3], (b,)))


def _fused_args(t, b, a, key=0):
    ks = jax.random.split(jax.random.key(key), 6)
    logits = jax.random.normal(ks[0], (t, b, a)) * 2.0
    onehot = jax.nn.one_hot(jax.random.randint(ks[1], (t, b), 0, a), a)
    blogp = jnp.sum(jax.nn.log_softmax(
        logits + jax.random.normal(ks[2], (t, b, a)) * 0.3) * onehot, -1)
    disc = jnp.full((t, b), 0.99)
    rew = jax.random.normal(ks[3], (t, b))
    v = jax.random.normal(ks[4], (t, b))
    vtp1 = jnp.concatenate([v[1:], jnp.zeros((1, b))], 0)
    return logits, onehot, blogp, disc, rew, v, vtp1


def _unfused_loss_parts(logits, onehot, blogp, disc, rew, v, vtp1):
    """The XLA composition the fused kernel replaces: log-softmax +
    rho/c clipping + the lax.scan V-trace recursion."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    p = jnp.exp(logp)
    tlp = jnp.sum(logp * onehot, -1)
    ne = jnp.sum(p * logp, -1)
    # vtrace_scan is batch-major; transpose in and back out
    out = vt.vtrace_scan(
        jnp.moveaxis(jax.lax.stop_gradient(tlp) - blogp, 0, 1),
        jnp.moveaxis(disc, 0, 1), jnp.moveaxis(rew, 0, 1),
        jnp.moveaxis(v, 0, 1), vtp1[-1])
    return (tlp, ne, jnp.moveaxis(out.vs, 0, 1),
            jnp.moveaxis(out.pg_advantages, 0, 1))


def run() -> None:
    for (b, t, tag) in [(32, 100, "paper_n100_b32"),
                        (256, 1024, "train1k_b256")]:
        args = _args(b, t)
        scan = jax.jit(lambda *a: vt.vtrace_scan(*a).vs)
        us = timeit(lambda: jax.block_until_ready(scan(*args)), n=20)
        emit(f"vtrace/{tag}/scan", us, f"tokens_per_s={b*t/us*1e6:.0f}")
        from repro.kernels import ops
        pal = lambda: jax.block_until_ready(
            ops.vtrace(*args, impl="pallas")[0])
        us_p = timeit(pal, n=3)
        emit(f"vtrace/{tag}/pallas_interpret", us_p,
             "interpret-mode (CPU correctness path, not TPU speed)")
    args = _args(8, 64)
    ref = jax.jit(lambda *a: vt.vtrace_reference(*a).vs)
    us_r = timeit(lambda: jax.block_until_ready(ref(*args)), n=5)
    emit("vtrace/ref_T64_b8/reference_quadratic", us_r, "oracle")
    run_fused()


def run_fused() -> None:
    """Fused loss/V-trace kernel vs its unfused XLA composition: the
    correctness delta is emitted always (this doubles as the CI kernels
    check); timing is one fused Pallas launch vs log-softmax + scan."""
    from repro.kernels.vtrace import loss_vtrace_pallas

    t, b, a = 100, 32, 16
    fa = _fused_args(t, b, a)
    fused = lambda: loss_vtrace_pallas(*fa)
    unfused = jax.jit(lambda *xs: _unfused_loss_parts(*xs))
    got = fused()
    want = unfused(*fa)
    err = max(float(jnp.max(jnp.abs(g - w))) for g, w in zip(got, want))
    emit("vtrace/fused_n100_b32_a16/max_abs_err_vs_unfused", 0.0,
         f"err={err:.2e} (tol 1e-5)")
    assert err <= 1e-5, f"fused != unfused: max abs err {err:.3e}"
    us_u = timeit(lambda: jax.block_until_ready(unfused(*fa)[2]), n=20)
    emit("vtrace/fused_n100_b32_a16/unfused_xla", us_u,
         f"tokens_per_s={t*b/us_u*1e6:.0f}")
    us_f = timeit(lambda: jax.block_until_ready(fused()[2]), n=3)
    on_tpu = jax.default_backend() == "tpu"
    emit("vtrace/fused_n100_b32_a16/fused_pallas", us_f,
         f"speedup_vs_unfused=x{us_u / max(us_f, 1e-9):.2f}" if on_tpu
         else "interpret-mode (CPU correctness path, not TPU speed)")
