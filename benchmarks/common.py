"""Shared benchmark utilities: CSV emission + the reusable training loop
(re-exported from repro.core.driver so examples don't depend on the
benchmarks package path)."""
from __future__ import annotations

import os
import time
from typing import Callable, List, Tuple

from repro.core.driver import run_training, small_arch  # noqa: F401

FAST = os.environ.get("BENCH_FAST", "0") == "1"

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def timeit(fn: Callable, n: int = 10, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us
