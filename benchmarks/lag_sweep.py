"""Paper Figure E.1: controlled policy-lag study. As the number of update
steps the actor policy is behind the learner grows, V-trace stays robust
while uncorrected learning degrades. Lag is exact and deterministic here
(LagController), unlike the load-dependent lag of the original."""
from __future__ import annotations

from benchmarks.common import FAST, emit, run_training
from repro.configs.base import ImpalaConfig

LAGS = [0, 2, 8, 16]


def run() -> None:
    steps = 120 if FAST else 250
    for mode in ("vtrace", "none"):
        row = []
        for lag in LAGS:
            icfg = ImpalaConfig(num_actions=4, unroll_length=16,
                                learning_rate=2e-3, entropy_cost=0.003,
                                rmsprop_eps=0.01, policy_lag=lag,
                                correction=mode)
            tracker, _ = run_training("bandit", icfg, num_envs=32,
                                      steps=steps, seed=13)
            row.append(tracker.mean_return(200))
        emit(f"lag_sweep/bandit/{mode}", 0.0,
             " ".join(f"lag{l}={r:.2f}" for l, r in zip(LAGS, row)))
