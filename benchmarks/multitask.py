"""Paper Tables 3/4 (miniature): one agent, one set of weights, trained on
a multi-task suite with fixed actor allocation per task; compared against
per-task experts on the mean capped normalised score (Appendix B metric).

Reference (human/random analogue) scores per env come from a scripted
near-optimal policy vs the random policy, measured on the fly.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, emit
from repro.configs.base import ImpalaConfig
from repro.configs.registry import get_smoke_config
from repro.core import actor as actor_lib
from repro.core import learner as learner_lib
from repro.core.metrics import EpisodeTracker, capped_normalised_score
from repro.core.queue import LagController
from repro.data.envs import make_env
from repro.models import backbone as bb
from repro.models import common as pcommon

TASKS = ["catch", "bandit", "tmaze"]
# measured reference scores (random policy, near-optimal) per task
REFS = {"catch": (-0.6, 1.0), "bandit": (0.25, 1.0), "tmaze": (-0.35, 1.0)}


def _train_multi(tasks: List[str], steps: int, num_envs_per_task: int = 8,
                 seed: int = 0) -> Dict[str, float]:
    """One set of weights; actors allocated per task (paper §5.3)."""
    envs = [make_env(t) for t in tasks]
    num_actions = max(e.num_actions for e in envs)
    hw = envs[0].image_hw
    # pad all task images to a common frame
    max_hw = (max(e.image_hw[0] for e in envs),
              max(e.image_hw[1] for e in envs), 3)
    arch = get_smoke_config("impala_shallow").replace(image_hw=max_hw)
    icfg = ImpalaConfig(num_actions=num_actions, unroll_length=16,
                        learning_rate=1e-3, entropy_cost=0.005,
                        rmsprop_eps=0.01, policy_lag=1)
    specs = bb.backbone_specs(arch, num_actions)
    params = pcommon.init_params(specs, jax.random.key(seed))
    train_step, opt = learner_lib.build_train_step(arch, icfg, num_actions)
    train_step = jax.jit(train_step)
    opt_state = opt.init(params)
    lag = LagController(icfg.policy_lag, params)

    actors = []
    for env in envs:
        def pad(env):
            base_init, base_unroll = actor_lib.build_actor(
                _padded(env, max_hw, num_actions), arch, icfg,
                num_envs_per_task)
            return base_init, base_unroll
        actors.append(pad(env))
    carries = [init(jax.random.key(seed + 10 + i))
               for i, (init, _) in enumerate(actors)]
    trackers = [EpisodeTracker(num_envs_per_task) for _ in tasks]

    for step in range(steps):
        batches = []
        for i, (init, unroll) in enumerate(actors):
            carries[i], traj = unroll(lag.actor_params(), carries[i])
            trackers[i].update(np.asarray(traj["rewards"]),
                               np.asarray(traj["done"]))
            batches.append(traj)
        batch = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *batches)
        params, opt_state, _ = train_step(params, opt_state,
                                          jnp.int32(step), batch)
        lag.on_update(params)
    return {t: trackers[i].mean_return(100) for i, t in enumerate(tasks)}


from repro.data.multitask import padded_env as _padded  # noqa: E402


def run() -> None:
    steps = 60 if FAST else 300
    multi = _train_multi(TASKS, steps)
    experts = {}
    for t in TASKS:
        experts[t] = _train_multi([t], steps)[t]
    rnd = [REFS[t][0] for t in TASKS]
    opt = [REFS[t][1] for t in TASKS]
    multi_score = capped_normalised_score([multi[t] for t in TASKS], opt, rnd)
    expert_score = capped_normalised_score([experts[t] for t in TASKS],
                                           opt, rnd)
    for t in TASKS:
        emit(f"multitask/{t}", 0.0,
             f"multi={multi[t]:.3f} expert={experts[t]:.3f}")
    emit("multitask/mean_capped_normalised", 0.0,
         f"multi={multi_score:.3f} experts={expert_score:.3f}")
