"""Paper Figure 4 (bottom row): robustness across hyperparameter
combinations. Sweep (learning rate x entropy cost) combinations for
V-trace vs no-correction under lag; report returns sorted high-to-low.
A flatter sorted curve = more robust (the paper's claim for IMPALA)."""
from __future__ import annotations

import itertools

import numpy as np

from benchmarks.common import FAST, emit, run_training
from repro.configs.base import ImpalaConfig

LRS = [5e-3, 2e-3, 5e-4]
ENTS = [0.01, 0.003, 0.0003]


def run() -> None:
    steps = 100 if FAST else 250
    for mode in ("vtrace", "none"):
        finals = []
        for lr, ent in itertools.product(LRS, ENTS):
            icfg = ImpalaConfig(num_actions=4, unroll_length=16,
                                learning_rate=lr, entropy_cost=ent,
                                rmsprop_eps=0.01, policy_lag=8,
                                correction=mode)
            tracker, _ = run_training("bandit", icfg, num_envs=16,
                                      steps=steps, seed=11)
            finals.append(tracker.mean_return(200))
        finals = sorted(finals, reverse=True)
        emit(f"stability/bandit/{mode}", 0.0,
             "sorted_returns=" + "|".join(f"{x:.2f}" for x in finals))
        emit(f"stability/bandit/{mode}/area", 0.0,
             f"mean={np.mean(finals):.3f} top3={np.mean(finals[:3]):.3f}")
