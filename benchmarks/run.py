# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   Table 1  -> benchmarks.throughput   (coupled vs decoupled FPS)
#   Table 2  -> benchmarks.corrections  (V-trace ablation +/- replay)
#   Fig. 4   -> benchmarks.stability    (hyperparameter robustness)
#   Fig. E.1 -> benchmarks.lag_sweep    (controlled policy lag)
#   Table 3/4-> benchmarks.multitask    (multi-task vs experts, capped score)
#   §3.1     -> benchmarks.vtrace_bench (learner V-trace microbench)
#   §Roofline-> python -m repro.roofline.table (reads results/dryrun)
#
# Set BENCH_FAST=1 for a quick pass.
import argparse
import sys
import time
import traceback


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", nargs="*", default=None,
                   help="subset: throughput corrections stability "
                        "lag_sweep multitask vtrace")
    args = p.parse_args()
    from benchmarks import (corrections, lag_sweep, multitask, stability,
                            throughput, vtrace_bench)
    suites = {
        "vtrace": vtrace_bench.run,
        "throughput": throughput.run,
        "corrections": corrections.run,
        "stability": stability.run,
        "lag_sweep": lag_sweep.run,
        "multitask": multitask.run,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if args.only and name not in args.only:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED: {failed}", flush=True)
        sys.exit(1)


if __name__ == '__main__':
    main()
