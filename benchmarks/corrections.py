"""Paper Table 2 (+ Fig. E.1): off-policy correction ablation under policy
lag, with and without replay. Four algorithms x {no-replay, replay} x
{bandit (fast, separates sharply), catch (control task)}; final mean
return reported (higher is better).

Expected qualitative result (= paper's): importance-sampling corrected
methods (vtrace, onestep_is) >> eps-correction ~= no-correction when the
actor policy lags the learner, with V-trace the most robust as the
off-policy gap widens (replay)."""
from __future__ import annotations

from benchmarks.common import FAST, emit, run_training
from repro.configs.base import ImpalaConfig

MODES = ["vtrace", "onestep_is", "eps", "none"]
ENVS = {
    # env: (num_actions, steps_fast, steps_full, lag, lr)
    "bandit": (4, 150, 300, 8, 2e-3),
    "catch": (3, 120, 500, 6, 6e-4),
}


def run() -> None:
    for env_name, (na, s_fast, s_full, lag, lr) in ENVS.items():
        steps = s_fast if FAST else s_full
        for replay in (False, True):
            for mode in MODES:
                icfg = ImpalaConfig(
                    num_actions=na, unroll_length=16, learning_rate=lr,
                    entropy_cost=0.003, rmsprop_eps=0.01, policy_lag=lag,
                    correction=mode,
                    replay_fraction=0.5 if replay else 0.0,
                    replay_capacity=256)
                tracker, _ = run_training(env_name, icfg, num_envs=32,
                                          steps=steps, seed=7)
                tag = "replay" if replay else "noreplay"
                emit(f"corrections/{env_name}/{mode}/{tag}", 0.0,
                     f"final_return={tracker.mean_return(200):.3f}")
        if FAST and env_name == "bandit":
            break  # keep the fast pass quick
