"""V-trace actor-critic losses (paper §4.2).

Total = pg_loss + baseline_cost * baseline_loss + entropy_cost * entropy_loss,
*summed* over batch and time (paper Table D.1 note: "the loss is summed
across the batch and time dimensions").
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ImpalaConfig
from repro.core import corrections, vtrace as vtrace_lib
from repro.kernels import vtrace as vtrace_kernels


def resolve_vtrace_impl(impl: str = "auto") -> str:
    """Map the ``auto`` V-trace implementation choice to a concrete one:
    the fused loss/V-trace Pallas kernel where it compiles for real
    (TPU), the ``lax.scan`` path everywhere else. Explicit choices pass
    through, so ablations and tests can still pin any implementation
    (``fused`` / ``pallas`` / ``scan`` / ``reference``)."""
    if impl != "auto":
        return impl
    return "fused" if jax.default_backend() == "tpu" else "scan"


def reward_clip(rewards: jax.Array, mode: str) -> jax.Array:
    if mode == "abs_one":
        return jnp.clip(rewards, -1.0, 1.0)
    if mode == "soft_asymmetric":
        # Optimistic Asymmetric Clipping (Fig. D.1):
        # 0.3 * min(tanh(r), 0) + 5.0 * max(tanh(r), 0)
        t = jnp.tanh(rewards)
        return 0.3 * jnp.minimum(t, 0.0) + 5.0 * jnp.maximum(t, 0.0)
    if mode == "none":
        return rewards
    raise ValueError(mode)


def policy_gradient_loss(logits, actions, advantages, eps: float = 0.0):
    """-(sum) adv * log pi(a|x); advantages are already stop-gradient."""
    if eps:
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        logp_all = jnp.log(probs + eps)
        logp = jnp.take_along_axis(logp_all, actions[..., None], axis=-1)[..., 0]
    else:
        logp = vtrace_lib.action_log_probs(logits, actions)
    return -jnp.sum(jax.lax.stop_gradient(advantages) * logp)


def baseline_loss(values, vs):
    """0.5 * sum (v_s - V(x_s))^2."""
    return 0.5 * jnp.sum(jnp.square(jax.lax.stop_gradient(vs) -
                                    values.astype(jnp.float32)))


def entropy_loss(logits):
    """Negative entropy summed (so that adding it *with positive coef*
    maximizes entropy): sum_s sum_a pi log pi."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(logp)
    return jnp.sum(p * logp)


def impala_loss(cfg: ImpalaConfig, target_logits, values, batch: Dict,
                impl: str = "auto", corr_values=None,
                corr_bootstrap=None, per_traj: bool = False
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """The full IMPALA learner loss on a batch of trajectories.

    batch: actions (B,T) int32, rewards (B,T) f32, discounts (B,T) f32,
           behaviour_logprob (B,T) f32.
    target_logits: (B,T,A) f32; values: (B,T) f32 — note the trained
    values cover steps 0..T-1 and the *bootstrap* V(x_T) must be provided
    as batch['bootstrap_value'] (B,), produced by evaluating the learner
    network on x_T (we evaluate on T+1 steps and split outside).

    ``corr_values``/``corr_bootstrap`` (replay path) substitute the
    V(x_s) the V-trace recursion reads — e.g. ``corrections.
    replay_baseline_mix``'s target-network baseline on replayed rows —
    while the baseline loss keeps training the online ``values`` toward
    the resulting vs. The fused kernel assumes the correction baseline
    IS the trained values, so this path pins the scan/pallas impl.
    ``per_traj=True`` adds ``vtrace/traj_adv_mag`` (B,), the
    per-trajectory |pg advantage| mean — the replay priority signal.
    """
    impl = resolve_vtrace_impl(impl)
    rewards = reward_clip(batch["rewards"], cfg.reward_clip)
    if impl == "fused" and corr_values is None and not per_traj:
        if (cfg.correction == "vtrace" and
                getattr(cfg, "pg_q_estimate", "vtrace") != "baseline_v"):
            return _impala_loss_fused(cfg, target_logits, values, batch,
                                      rewards)
    if impl == "fused":
        # ablation variants (and the replay baseline/per-traj paths)
        # keep their dedicated math; drop to the plain V-trace kernel
        # for whatever scan they do use
        impl = "pallas" if jax.default_backend() == "tpu" else "scan"
    vs, pg_adv = corrections.compute_correction(
        cfg, batch["behaviour_logprob"], target_logits, batch["actions"],
        batch["discounts"], rewards,
        values if corr_values is None else corr_values,
        (batch["bootstrap_value"] if corr_bootstrap is None
         else corr_bootstrap),
        impl=impl)
    eps = cfg.eps_correction if cfg.correction == "eps" else 0.0
    pg = policy_gradient_loss(target_logits, batch["actions"], pg_adv, eps)
    bl = baseline_loss(values, vs)
    ent = entropy_loss(target_logits)
    total = pg + cfg.baseline_cost * bl + cfg.entropy_cost * ent
    metrics = {
        "loss/total": total,
        "loss/pg": pg,
        "loss/baseline": bl,
        "loss/entropy": ent,
        "vtrace/mean_vs": jnp.mean(vs),
        "vtrace/mean_pg_adv": jnp.mean(pg_adv),
    }
    if per_traj:
        metrics["vtrace/traj_adv_mag"] = jnp.mean(jnp.abs(pg_adv), axis=1)
    return total, metrics


def _impala_loss_fused(cfg: ImpalaConfig, target_logits, values, batch,
                       rewards) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Fused-kernel assembly of the same total as ``impala_loss``: one
    Pallas launch produces target log-probs, per-step negative entropy,
    v_s targets and pg advantages; only the final reductions stay in
    XLA. Batch-major inputs are transposed to the kernel's time-major
    layout here."""
    num_actions = target_logits.shape[-1]
    logits = jnp.moveaxis(target_logits.astype(jnp.float32), 1, 0)
    onehot = jax.nn.one_hot(
        jnp.moveaxis(batch["actions"], 1, 0), num_actions,
        dtype=jnp.float32)
    values_f = values.astype(jnp.float32)
    v_tp1 = jnp.concatenate(
        [values_f[:, 1:],
         batch["bootstrap_value"].astype(jnp.float32)[:, None]], axis=1)
    tm = lambda x: jnp.moveaxis(x.astype(jnp.float32), 1, 0)  # noqa: E731
    tlp, ne, vs, pg_adv = vtrace_kernels.fused_loss_vtrace(
        logits, onehot, tm(batch["behaviour_logprob"]),
        tm(batch["discounts"]), tm(rewards), tm(values_f), tm(v_tp1),
        cfg.rho_bar, cfg.c_bar, cfg.lambda_)
    vs = jax.lax.stop_gradient(vs)
    pg_adv = jax.lax.stop_gradient(pg_adv)
    pg = -jnp.sum(pg_adv * tlp)
    bl = 0.5 * jnp.sum(jnp.square(vs - jnp.moveaxis(values_f, 1, 0)))
    ent = jnp.sum(ne)
    total = pg + cfg.baseline_cost * bl + cfg.entropy_cost * ent
    metrics = {
        "loss/total": total,
        "loss/pg": pg,
        "loss/baseline": bl,
        "loss/entropy": ent,
        "vtrace/mean_vs": jnp.mean(vs),
        "vtrace/mean_pg_adv": jnp.mean(pg_adv),
    }
    return total, metrics
