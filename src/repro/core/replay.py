"""Prioritized trajectory replay (paper §5.2.2, Ape-X / IMPACT hybrid).

A circular host-side buffer of *completed trajectories*. Each stored
trajectory is one contiguous spec-described ``serde`` buffer (the same
layout the transports ship) rather than a per-leaf pytree split: one
compact allocation per item, structure/dtype round-trip (lstm-state
tuples included) for free, and decode is zero-copy views into the
stored bytes.

Sampling is proportional prioritization per Distributed Prioritized
Experience Replay: ``priority='pertd'`` draws items with probability
proportional to their stored priority (the V-trace advantage magnitude
of the last training pass — set on insert, updated after every replayed
step); ``priority='uniform'`` is the paper's §5.2.2 uniform mix.
``reuse_limit`` caps how many times one trajectory may be consumed in
total (the IMPACT-style K), after which the slot is retired.

Everything here stays on the host: ``sample``/``sample_items`` return
numpy trees (``np.stack``, never device arrays) so the learner's staged
``_HostStager`` path keeps its single ``device_put`` per batch.

Deliberately no jax import at module level — ``distributed.learner``
(itself jax-free at import) builds a ``ReplayBuffer`` before jax is
paid for, and the sync driver's device trees are handled by
``np.asarray`` on encode. ``mix_batches`` imports jax lazily only when
handed device leaves.
"""
from __future__ import annotations

import collections
import math
from typing import Any, Dict, List, Optional

import numpy as np

from repro.distributed import serde

PyTree = Any

# the fold prime shared with supervise.fold_restart_seed: replay RNG
# streams are (seed, learner_id)-deterministic, never the hardcoded
# default_rng(0) every replica used to share
_SEED_FOLD_PRIME = 1_000_003

PRIORITY_MODES = ("uniform", "pertd")


def fold_replay_seed(seed: int, learner_id: int) -> int:
    """Fold a learner id into the run seed (same discipline as
    ``supervise.fold_restart_seed``): learner 0 of a group — and the
    single-learner run — keeps the raw seed; every other replica gets
    its own deterministic stream."""
    if learner_id == 0:
        return seed
    return (seed + learner_id * _SEED_FOLD_PRIME) % (2 ** 31 - 1)


class _Slot:
    """One stored trajectory: the encoded serde buffer + sampling
    state. ``uid`` is a monotonically increasing insert id, so a
    priority update that arrives after the slot was overwritten (FIFO)
    or retired (reuse-exhausted) is dropped instead of retagging an
    unrelated trajectory."""

    __slots__ = ("buf", "uid", "version", "priority", "uses")

    def __init__(self, buf: bytes, uid: int, version: int,
                 priority: float, uses: int):
        self.buf = buf
        self.uid = uid
        self.version = version
        self.priority = priority
        self.uses = uses


class ReplaySample:
    """What ``sample_items`` hands back per draw: the decoded item plus
    the bookkeeping the learner needs to update the priority after the
    replayed step."""

    __slots__ = ("item", "uid", "priority", "version")

    def __init__(self, item: serde.TrajectoryItem, uid: int,
                 priority: float, version: int):
        self.item = item
        self.uid = uid
        self.priority = priority
        self.version = version


def _stack_trees(trees: List[PyTree]) -> PyTree:
    """np.stack a list of structurally identical trees (jax-free
    recursion mirroring serde's node kinds)."""
    first = trees[0]
    if first is None:
        return None
    if isinstance(first, dict):
        return {k: _stack_trees([t[k] for t in trees]) for k in first}
    if isinstance(first, (list, tuple)):
        out = [_stack_trees([t[i] for t in trees])
               for i in range(len(first))]
        return tuple(out) if isinstance(first, tuple) else out
    return np.stack([np.asarray(t) for t in trees])


def _host_tree(tree: PyTree) -> PyTree:
    """np.asarray every leaf (one D2H copy per leaf for device trees)."""
    if tree is None:
        return None
    if isinstance(tree, dict):
        return {k: _host_tree(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        out = [_host_tree(v) for v in tree]
        return tuple(out) if isinstance(tree, tuple) else out
    return np.asarray(tree)


def _index_tree(tree: PyTree, i: int) -> PyTree:
    """tree[i] along the leading axis of every (host) leaf."""
    if tree is None:
        return None
    if isinstance(tree, dict):
        return {k: _index_tree(v, i) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        out = [_index_tree(v, i) for v in tree]
        return tuple(out) if isinstance(tree, tuple) else out
    return tree[i]


def _tree_leading_dim(tree: PyTree) -> int:
    if tree is None:
        return 0
    if isinstance(tree, dict):
        for v in tree.values():
            n = _tree_leading_dim(v)
            if n:
                return n
        return 0
    if isinstance(tree, (list, tuple)):
        for v in tree:
            n = _tree_leading_dim(v)
            if n:
                return n
        return 0
    shape = getattr(tree, "shape", None)    # no D2H copy for jax leaves
    if shape is None:
        shape = np.asarray(tree).shape
    return shape[0] if shape else 0


class ReplayBuffer:
    """Circular prioritized trajectory replay (module docstring).

    Identity of the sample stream is ``(seed, learner_id)`` — pass
    ``seed`` (+ ``learner_id`` under a group) or an explicit ``rng``;
    there is deliberately no default generator, because a hardcoded one
    made every replica (and every run) draw identical indices.
    """

    def __init__(self, capacity: int,
                 rng: Optional[np.random.Generator] = None, *,
                 seed: Optional[int] = None, learner_id: int = 0,
                 reuse_limit: int = 0, priority: str = "pertd",
                 priority_eps: float = 1e-3):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if priority not in PRIORITY_MODES:
            raise ValueError(f"priority must be one of {PRIORITY_MODES}, "
                             f"got {priority!r}")
        if rng is None:
            if seed is None:
                raise ValueError(
                    "ReplayBuffer needs an explicit rng or seed: a "
                    "default generator would give every learner replica "
                    "the identical sample-index stream")
            rng = np.random.default_rng(fold_replay_seed(seed, learner_id))
        self.capacity = capacity
        self.reuse_limit = int(reuse_limit)
        self.priority_mode = priority
        self.priority_eps = float(priority_eps)
        self._rng = rng
        self._slots: List[Optional[_Slot]] = []
        self._next = 0
        self._live = 0
        self._next_uid = 0
        self._max_priority = 1.0
        # honest accounting (the satellite fix): everything that enters,
        # leaves, or is displaced around this buffer is counted
        self.added = 0
        self.sampled = 0
        self.displaced = 0
        self.evicted_fifo = 0
        self.evicted_exhausted = 0
        self.starved = 0
        self.staleness_hist: collections.Counter = collections.Counter()

    # ------------------------------------------------------------------
    # insert

    def add_item(self, item: serde.TrajectoryItem,
                 priority: Optional[float] = None, uses: int = 0) -> int:
        """Store one trajectory; returns its uid. ``priority=None``
        means "not yet trained on" — Ape-X's max-priority default, so a
        never-scored trajectory is sampled eagerly rather than starved.
        ``uses`` pre-counts consumptions (a trajectory that already had
        its online pass enters with ``uses=1``)."""
        if priority is None:
            priority = self._max_priority
        else:
            priority = float(priority)
            self._max_priority = max(self._max_priority, priority)
        if item.trace is not None:
            # replayed items must not re-enter the trace recorder's
            # lifecycle accounting; store them unstamped
            item = serde.TrajectoryItem(item.data, item.param_version,
                                        item.actor_id, item.produced_at)
        slot = _Slot(serde.encode_item(item), self._next_uid,
                     int(item.param_version), priority, int(uses))
        self._next_uid += 1
        if self.reuse_limit and slot.uses >= self.reuse_limit:
            # nothing left to consume; don't occupy a ring slot
            self.added += 1
            self.evicted_exhausted += 1
            return slot.uid
        if len(self._slots) < self.capacity:
            self._slots.append(slot)
        else:
            if self._slots[self._next] is not None:
                self.evicted_fifo += 1
                self._live -= 1
            self._slots[self._next] = slot
            self._next = (self._next + 1) % self.capacity
        self._live += 1
        self.added += 1
        return slot.uid

    def add_batch(self, traj_batch: PyTree, param_version: int = 0,
                  priority: Optional[float] = None) -> List[int]:
        """Split a batched trajectory pytree (leading batch dim) into
        per-env trajectories and store each — the sync driver's insert
        path. Handles lstm-state tuples etc. through the serde layout."""
        b = _tree_leading_dim(traj_batch)
        host = _host_tree(traj_batch)
        return [
            self.add_item(serde.TrajectoryItem(
                _index_tree(host, i), param_version, 0, 0.0),
                priority=priority)
            for i in range(b)
        ]

    def note_displaced(self, n: int) -> None:
        """Count trajectories a ``mix_batches`` call displaced from an
        online batch (they live in this buffer; their online pass was
        traded for replayed rows)."""
        self.displaced += int(n)

    # ------------------------------------------------------------------
    # sample

    def __len__(self) -> int:
        return self._live

    def num_sampleable(self) -> int:
        return self._live

    def _live_slots(self) -> List[_Slot]:
        return [s for s in self._slots if s is not None]

    def sampling_probs(self) -> Dict[int, float]:
        """uid -> draw probability under the current priorities (the
        testable core of the prioritization math)."""
        live = self._live_slots()
        if not live:
            return {}
        p = self._probs(live)
        return {s.uid: float(q) for s, q in zip(live, p)}

    def _probs(self, live: List[_Slot]) -> np.ndarray:
        if self.priority_mode == "uniform":
            return np.full(len(live), 1.0 / len(live))
        w = np.array([max(s.priority, 0.0) + self.priority_eps
                      for s in live], np.float64)
        return w / w.sum()

    def sample_items(self, n: int, version_now: Optional[int] = None
                     ) -> Optional[List[ReplaySample]]:
        """Draw ``n`` distinct trajectories (proportional or uniform);
        None when occupancy can't cover the request (the caller trains
        pure-online that round). Decoded leaves are host numpy views of
        the stored buffer — no device materialization here."""
        if n < 1:
            return []
        live = self._live_slots()
        if len(live) < n:
            self.starved += 1
            return None
        idx = self._rng.choice(len(live), size=n, replace=False,
                               p=self._probs(live))
        out = []
        for i in idx:
            s = live[int(i)]
            s.uses += 1
            self.sampled += 1
            if version_now is not None:
                self.staleness_hist[max(0, version_now - s.version)] += 1
            out.append(ReplaySample(serde.decode_item(s.buf), s.uid,
                                    s.priority, s.version))
        if self.reuse_limit:
            self._retire_exhausted()
        return out

    def sample(self, n: int) -> Optional[PyTree]:
        """Legacy batch draw: ``n`` trajectories stacked along a fresh
        leading axis as host numpy (``np.stack`` — the jnp.stack of the
        seed forced a hidden H2D round-trip per sample); None under
        occupancy."""
        samples = self.sample_items(n)
        if samples is None:
            return None
        return _stack_trees([s.item.data for s in samples])

    def _retire_exhausted(self) -> None:
        for j, s in enumerate(self._slots):
            if s is not None and s.uses >= self.reuse_limit:
                self._slots[j] = None
                self._live -= 1
                self.evicted_exhausted += 1

    # ------------------------------------------------------------------
    # priorities

    def update_priorities(self, uids: List[int], priorities) -> int:
        """Re-score trajectories after a replayed (or first online)
        pass; stale uids — already overwritten or retired — are
        silently skipped. Returns how many updates landed."""
        by_uid = {s.uid: s for s in self._slots if s is not None}
        hit = 0
        for uid, p in zip(uids, priorities):
            s = by_uid.get(int(uid))
            if s is None:
                continue
            s.priority = float(p)
            self._max_priority = max(self._max_priority, s.priority)
            hit += 1
        return hit

    # ------------------------------------------------------------------
    # telemetry

    def priority_histogram(self) -> Dict[int, int]:
        """log2-bucketed histogram of live priorities (bucket k counts
        priorities in [2^k, 2^(k+1)))."""
        hist: collections.Counter = collections.Counter()
        for s in self._slots:
            if s is not None:
                hist[int(math.floor(math.log2(max(s.priority,
                                                  self.priority_eps))))] += 1
        return dict(sorted(hist.items()))

    def snapshot(self) -> Dict[str, Any]:
        stale = dict(sorted(self.staleness_hist.items()))
        n_stale = sum(stale.values())
        return {
            "capacity": self.capacity,
            "occupancy": self._live,
            "added": self.added,
            "sampled": self.sampled,
            "displaced": self.displaced,
            "evicted_fifo": self.evicted_fifo,
            "evicted_exhausted": self.evicted_exhausted,
            "starved": self.starved,
            "reuse_limit": self.reuse_limit,
            "priority_mode": self.priority_mode,
            "priority_hist": self.priority_histogram(),
            "staleness": {
                "hist": stale,
                "mean": (sum(k * v for k, v in stale.items()) / n_stale
                         if n_stale else 0.0),
                "max": max(stale) if stale else 0,
                "measured": n_stale,
            },
        }


# ---------------------------------------------------------------------------
# batch mixing


def plan_mix(num_fresh: int, max_total: int, fraction: float,
             available: int) -> int:
    """How many replayed trajectories to add to ``num_fresh`` online
    ones: the largest power-of-two total batch <= ``max_total`` whose
    replayed share ``total - num_fresh`` stays within ``round(fraction
    * total)`` and within the buffer's ``available`` stock. Returns the
    replayed count (0 = train pure online).

    This is the learner-side *top-up* shape of the paper's 50% mix:
    fresh consumption per update shrinks by (1 - fraction) while the
    trained batch stays bucket-sized — that is where the
    frames-to-return win comes from."""
    if num_fresh < 1 or fraction <= 0.0 or available < 1:
        return 0
    best = 0
    total = 1
    while total < num_fresh:
        total *= 2
    while total <= max_total:
        n_rep = total - num_fresh
        if 0 < n_rep <= min(int(round(fraction * total)), available):
            best = n_rep
        total *= 2
    return best


def mix_batches(online: PyTree, replayed: Optional[PyTree],
                replay_fraction: float,
                buffer: Optional[ReplayBuffer] = None) -> PyTree:
    """Replace the first ``replay_fraction`` of the online batch with
    replayed trajectories (paper: 50% from replay). Host numpy batches
    stay host numpy (np.concatenate); device leaves concatenate on
    device. The ``k`` displaced online trajectories are counted into
    ``buffer`` (``replay.displaced``) — they were stored there by the
    caller's ``add_batch`` and get their training pass via a later
    sample, so frame accounting stays honest."""
    if replayed is None or replay_fraction <= 0:
        return online
    b = _tree_leading_dim(online)
    n_rep = _tree_leading_dim(replayed)
    k = min(int(round(b * replay_fraction)), n_rep)
    if k == 0:
        return online
    if buffer is not None:
        buffer.note_displaced(k)

    def cat(o, r):
        if isinstance(o, np.ndarray) and isinstance(r, np.ndarray):
            return np.concatenate([r[:k], o[k:]], axis=0)
        import jax.numpy as jnp
        return jnp.concatenate([r[:k], o[k:]], axis=0)

    def walk(o, r):
        if o is None:
            return None
        if isinstance(o, dict):
            return {key: walk(o[key], r[key]) for key in o}
        if isinstance(o, (list, tuple)):
            out = [walk(x, y) for x, y in zip(o, r)]
            return tuple(out) if isinstance(o, tuple) else out
        return cat(o, r)

    return walk(online, replayed)
