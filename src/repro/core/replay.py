"""Experience replay (paper §5.2.2): FIFO buffer of trajectory batches,
uniform sampling, used to mix 50% replayed items into each learner batch —
which widens the pi/mu gap and is where V-trace shines (Table 2).
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class ReplayBuffer:
    """Stores individual trajectories (split from actor batches) on host."""

    def __init__(self, capacity: int, rng: Optional[np.random.Generator] = None):
        self.capacity = capacity
        self._items: List[PyTree] = []
        self._next = 0
        self._rng = rng or np.random.default_rng(0)

    def add_batch(self, traj_batch: PyTree) -> None:
        """traj_batch: pytree with leading batch dim; split and store."""
        leaves = jax.tree.leaves(traj_batch)
        if not leaves:
            return
        b = leaves[0].shape[0]
        host = jax.tree.map(np.asarray, traj_batch)
        for i in range(b):
            item = jax.tree.map(lambda x: x[i], host)
            if len(self._items) < self.capacity:
                self._items.append(item)
            else:  # FIFO removal
                self._items[self._next] = item
                self._next = (self._next + 1) % self.capacity
        # note: lstm_state tuples etc. are handled transparently by tree.map

    def sample(self, n: int) -> Optional[PyTree]:
        if len(self._items) < n:
            return None
        idx = self._rng.integers(0, len(self._items), size=n)
        items = [self._items[i] for i in idx]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *items)

    def __len__(self) -> int:
        return len(self._items)


def mix_batches(online: PyTree, replayed: Optional[PyTree],
                replay_fraction: float) -> PyTree:
    """Replace the first ``replay_fraction`` of the online batch with
    replayed trajectories (paper: 50% uniform from replay)."""
    if replayed is None or replay_fraction <= 0:
        return online
    b = jax.tree.leaves(online)[0].shape[0]
    n_rep = jax.tree.leaves(replayed)[0].shape[0]
    k = min(int(round(b * replay_fraction)), n_rep)
    if k == 0:
        return online
    return jax.tree.map(
        lambda o, r: jnp.concatenate([r[:k], o[k:]], axis=0),
        online, replayed)
