"""The four off-policy correction variants compared in paper §5.2.2:

  1. 'none'       — no correction (on-policy n-step Bellman targets and
                    plain advantages, even though the data is off-policy).
  2. 'eps'        — like 'none', but log pi is computed as log(pi + eps)
                    in the policy-gradient loss (GA3C-style stabilizer).
  3. 'onestep_is' — no correction of V targets; the policy gradient
                    advantage is multiplied by the 1-step truncated IS
                    weight rho_s ("V-trace without traces").
  4. 'vtrace'     — full V-trace (Eq. 1).

Each returns (vs, pg_advantages) as (B, T) stop-gradient arrays.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ImpalaConfig
from repro.core import vtrace as vtrace_lib


def replay_baseline_mix(values, target_values, replay_mask):
    """IMPACT-style mixed correction baseline for a batch holding both
    online and replayed trajectories: rows flagged by ``replay_mask``
    (B,) take the *target network's* values as the V-trace recursion's
    V(x_s) — a periodic copy of the learner params, so K replays of one
    trajectory chase a fixed target instead of their own moving output —
    while online rows keep the learner's own values. The result feeds
    ``compute_correction`` as its ``values`` argument; it is
    stop-gradient because correction outputs are targets either way
    (the baseline loss still trains the *online* values toward vs)."""
    m = replay_mask.astype(jnp.float32)
    m = m.reshape(m.shape + (1,) * (values.ndim - 1))
    mixed = (m * target_values.astype(jnp.float32) +
             (1.0 - m) * values.astype(jnp.float32))
    return jax.lax.stop_gradient(mixed)


def nstep_returns(discounts, rewards, values, bootstrap_value):
    """On-policy n-step Bellman targets (Eq. 2): reverse scan of
    G_s = r_s + gamma_s G_{s+1}, G_n = bootstrap."""
    def body(acc, xs):
        r, d = xs
        acc = r + d * acc
        return acc, acc

    xs = (jnp.moveaxis(rewards.astype(jnp.float32), 1, 0),
          jnp.moveaxis(discounts.astype(jnp.float32), 1, 0))
    _, gs = jax.lax.scan(body, bootstrap_value.astype(jnp.float32), xs,
                         reverse=True)
    del values
    return jnp.moveaxis(gs, 0, 1)


def compute_correction(cfg: ImpalaConfig, behaviour_logprob, target_logits,
                       actions, discounts, rewards, values, bootstrap_value,
                       impl: str = "scan") -> Tuple[jax.Array, jax.Array]:
    """Dispatch on cfg.correction. Returns (vs, pg_advantages)."""
    mode = cfg.correction
    if mode == "vtrace":
        ret = vtrace_lib.vtrace_from_logits(
            behaviour_logprob, target_logits, actions, discounts, rewards,
            values, bootstrap_value, rho_bar=cfg.rho_bar, c_bar=cfg.c_bar,
            lambda_=cfg.lambda_, impl=impl)
        pg_adv = ret.pg_advantages
        if getattr(cfg, "pg_q_estimate", "vtrace") == "baseline_v":
            # Appendix E.3 variant: q_s = r_s + gamma V(x_{s+1}) — uses no
            # information from the current rollout beyond one step (worse
            # in the paper's Figs. E.3/E.4; kept for the ablation).
            logp = vtrace_lib.action_log_probs(target_logits, actions)
            rho = jnp.exp(logp - behaviour_logprob)
            if cfg.rho_bar is not None:
                rho = jnp.minimum(cfg.rho_bar, rho)
            v_tp1 = jnp.concatenate(
                [values[:, 1:].astype(jnp.float32),
                 bootstrap_value.astype(jnp.float32)[:, None]], axis=1)
            pg_adv = rho * (rewards.astype(jnp.float32) +
                            discounts.astype(jnp.float32) * v_tp1 -
                            values.astype(jnp.float32))
            pg_adv = jax.lax.stop_gradient(pg_adv)
        return ret.vs, pg_adv

    vs = nstep_returns(discounts, rewards, values, bootstrap_value)
    vs_tp1 = jnp.concatenate(
        [vs[:, 1:], bootstrap_value.astype(jnp.float32)[:, None]], axis=1)
    adv = (rewards.astype(jnp.float32) + discounts.astype(jnp.float32) *
           vs_tp1 - values.astype(jnp.float32))
    if mode == "onestep_is":
        logp = vtrace_lib.action_log_probs(target_logits, actions)
        rho = jnp.exp(logp - behaviour_logprob)
        if cfg.rho_bar is not None:
            rho = jnp.minimum(cfg.rho_bar, rho)
        adv = rho * adv
    elif mode in ("none", "eps"):
        pass  # 'eps' only changes the log-prob inside the loss
    else:
        raise ValueError(mode)
    return jax.lax.stop_gradient(vs), jax.lax.stop_gradient(adv)
