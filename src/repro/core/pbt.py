"""Population Based Training (paper Appendix F).

Rules implemented exactly as described:
  * burn-in period with no evolution;
  * exploit: pick a random other member; if its fitness is more than an
    absolute 5% higher, copy its weights and hyperparameters;
  * explore: each hyperparameter (entropy cost, learning rate, RMSProp
    eps) is permuted with probability 1/3 by multiplying with 1.2 or
    1/1.2 (unbiased, unlike Jaderberg et al.'s 1.2/0.8) — applied whether
    or not a copy happened.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

PyTree = Any

PERTURBABLE = ("entropy_cost", "learning_rate", "rmsprop_eps")


@dataclasses.dataclass
class PBTMember:
    hypers: Dict[str, float]
    fitness: float = -np.inf
    copied_from: Optional[int] = None


class PBTController:
    def __init__(self, pop_size: int, seed: int = 0,
                 burn_in_steps: int = 0, threshold: float = 0.05,
                 perturb_prob: float = 1.0 / 3.0, factor: float = 1.2,
                 ranges: Optional[Dict[str, Tuple[float, float]]] = None):
        self.rng = np.random.default_rng(seed)
        self.burn_in_steps = burn_in_steps
        self.threshold = threshold
        self.perturb_prob = perturb_prob
        self.factor = factor
        ranges = ranges or {
            # paper Table D.1 (log-uniform; eps categorical approximated)
            "entropy_cost": (5e-5, 1e-2),
            "learning_rate": (5e-6, 5e-3),
            "rmsprop_eps": (1e-7, 1e-1),
        }
        self.members: List[PBTMember] = []
        for _ in range(pop_size):
            h = {k: float(np.exp(self.rng.uniform(np.log(lo), np.log(hi))))
                 for k, (lo, hi) in ranges.items()}
            self.members.append(PBTMember(hypers=h))

    def report_fitness(self, idx: int, fitness: float) -> None:
        self.members[idx].fitness = float(fitness)

    def exploit_explore(self, idx: int, step: int,
                        weights: List[PyTree]) -> Tuple[Dict[str, float], bool]:
        """Returns (new hypers for member idx, copied?). ``weights`` is the
        mutable list of per-member parameter pytrees; on exploit the
        source member's weights are copied into slot ``idx``."""
        m = self.members[idx]
        copied = False
        if step >= self.burn_in_steps and len(self.members) > 1:
            other_idx = int(self.rng.integers(0, len(self.members)))
            while other_idx == idx:
                other_idx = int(self.rng.integers(0, len(self.members)))
            other = self.members[other_idx]
            if other.fitness > m.fitness + self.threshold:
                m.hypers = dict(other.hypers)
                m.copied_from = other_idx
                if weights is not None:
                    weights[idx] = weights[other_idx]
                copied = True
        # explore happens whether or not a copy happened (Appendix F)
        for k in PERTURBABLE:
            if k in m.hypers and self.rng.random() < self.perturb_prob:
                mult = self.factor if self.rng.random() < 0.5 else 1.0 / self.factor
                m.hypers[k] = float(m.hypers[k] * mult)
        return dict(m.hypers), copied

    def best(self) -> int:
        return int(np.argmax([m.fitness for m in self.members]))
