"""The IMPALA actor: forward-only policy inference against vectorized
environments, emitting trajectories of (x_t, a_t, r_t, mu(a_t|x_t)) plus
the initial recurrent state (paper §3).

The actor's params are *stale* (k learner updates behind) — the driver
controls the lag, which V-trace corrects on the learner. One ``unroll``
call = one n-step trajectory batch, jitted end-to-end (the TPU/CPU
analogue of the paper's dynamic-batched actor inference).

Two agent kinds:
  * impala_cnn — conv torso + LSTM; recurrent state carried across unrolls
    and shipped with the trajectory (exactly the paper).
  * token backbones — per-step `apply_decode` with a KV/recurrent cache;
    the cache is reset at each unroll boundary (context = unroll).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ImpalaConfig
from repro.data.envs import Env
from repro.models import backbone as bb
from repro.models import lstm as lstm_lib

PyTree = Any


class ActorCarry(NamedTuple):
    env_state: PyTree
    rng: jax.Array
    obs_token: jax.Array       # (B,)
    obs_image: jax.Array       # (B, H, W, C)
    last_action: jax.Array     # (B,)
    last_reward: jax.Array     # (B,)
    done: jax.Array            # (B,)
    lstm_state: PyTree         # ((B,W),(B,W)) or None-like zeros


def build_actor(env: Env, arch_cfg: ArchConfig, cfg: ImpalaConfig,
                num_envs: int):
    """Returns (init_fn, unroll_fn).

    init_fn(key) -> ActorCarry
    unroll_fn(params, carry) -> (carry, trajectory dict)  [jitted]
    """
    num_actions = env.num_actions
    t_len = cfg.unroll_length
    is_cnn = arch_cfg.family == "impala_cnn"

    def init_fn(key) -> ActorCarry:
        keys = jax.random.split(key, num_envs + 1)
        env_state = jax.vmap(env.reset)(keys[1:])
        ts = jax.vmap(env.observe)(env_state)
        lstm_state = lstm_lib.lstm_zero_state(num_envs, arch_cfg.lstm_width)
        return ActorCarry(env_state, keys[0], ts.obs_token, ts.obs_image,
                          jnp.zeros((num_envs,), jnp.int32),
                          jnp.zeros((num_envs,), jnp.float32),
                          jnp.zeros((num_envs,), bool),
                          lstm_state)

    def policy_step_cnn(params, carry: ActorCarry):
        batch = {
            "image": carry.obs_image[:, None],
            "last_action": carry.last_action[:, None],
            "last_reward": carry.last_reward[:, None],
            "done": carry.done[:, None],
            "lstm_state": carry.lstm_state,
        }
        out = bb.apply_train(params, batch, arch_cfg, num_actions)
        return out.policy_logits[:, 0], out.cache  # cache = new lstm state

    def sample(key, logits):
        return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)

    if is_cnn:
        def unroll(params, carry: ActorCarry):
            initial_lstm = carry.lstm_state

            def step(c: ActorCarry, _):
                rng, k_act, k_env = jax.random.split(c.rng, 3)
                logits, lstm_state = policy_step_cnn(params, c)
                action = sample(k_act, logits)
                logp = jax.nn.log_softmax(logits)[
                    jnp.arange(num_envs), action]
                env_keys = jax.random.split(k_env, num_envs)
                env_state, ts = jax.vmap(env.step)(c.env_state, action,
                                                   env_keys)
                out = {"obs_token": c.obs_token, "obs_image": c.obs_image,
                       "last_action": c.last_action,
                       "last_reward": c.last_reward, "done_in": c.done,
                       "action": action, "reward": ts.reward,
                       "done": ts.done, "behaviour_logprob": logp}
                nc = ActorCarry(env_state, rng, ts.obs_token, ts.obs_image,
                                action, ts.reward, ts.done, lstm_state)
                return nc, out

            carry2, traj = jax.lax.scan(step, carry, None, length=t_len)
            traj = jax.tree.map(lambda x: jnp.moveaxis(x, 0, 1), traj)
            traj = _finalize(traj, carry2, initial_lstm)
            return carry2, traj
    else:
        cache_len = t_len + 1

        def unroll(params, carry: ActorCarry):
            cache = bb.cache_init(num_envs, cache_len, arch_cfg)

            def step(state, i):
                c, cache = state
                rng, k_act, k_env = jax.random.split(c.rng, 3)
                out = bb.apply_decode(params, c.obs_token[:, None], cache,
                                      i.astype(jnp.int32), arch_cfg,
                                      num_actions)
                logits = out.policy_logits[:, 0]
                action = sample(k_act, logits)
                logp = jax.nn.log_softmax(logits)[
                    jnp.arange(num_envs), action]
                env_keys = jax.random.split(k_env, num_envs)
                env_state, ts = jax.vmap(env.step)(c.env_state, action,
                                                   env_keys)
                outp = {"obs_token": c.obs_token,
                        "last_action": c.last_action,
                        "last_reward": c.last_reward, "done_in": c.done,
                        "action": action, "reward": ts.reward,
                        "done": ts.done, "behaviour_logprob": logp}
                nc = ActorCarry(env_state, rng, ts.obs_token, c.obs_image,
                                action, ts.reward, ts.done, c.lstm_state)
                return (nc, out.cache), outp

            (carry2, _), traj = jax.lax.scan(step, (carry, cache),
                                             jnp.arange(t_len))
            traj = jax.tree.map(lambda x: jnp.moveaxis(x, 0, 1), traj)
            traj = _finalize(traj, carry2, None)
            return carry2, traj

    def _finalize(traj: Dict, carry2: ActorCarry, initial_lstm):
        """Append bootstrap observation x_{n+1} and package."""
        out = {
            "actions": traj["action"],
            "rewards": traj["reward"],
            "discounts": cfg.discount * (1.0 -
                                         traj["done"].astype(jnp.float32)),
            "behaviour_logprob": traj["behaviour_logprob"],
            "done": traj["done"],
        }
        if is_cnn:
            out["obs_image"] = jnp.concatenate(
                [traj["obs_image"], carry2.obs_image[:, None]], axis=1)
            out["last_action"] = jnp.concatenate(
                [traj["last_action"], carry2.last_action[:, None]], axis=1)
            out["last_reward"] = jnp.concatenate(
                [traj["last_reward"], carry2.last_reward[:, None]], axis=1)
            out["done_in"] = jnp.concatenate(
                [traj["done_in"], carry2.done[:, None]], axis=1)
            out["lstm_state"] = initial_lstm
        else:
            out["obs_token"] = jnp.concatenate(
                [traj["obs_token"], carry2.obs_token[:, None]], axis=1)
        return out

    return init_fn, jax.jit(unroll)
