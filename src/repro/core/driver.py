"""Reusable CPU training loop: the IMPALA pipeline (actors -> queue with
policy lag -> V-trace learner, optional replay) over a named env. Used by
benchmarks, examples, and tests."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ImpalaConfig
from repro.configs.registry import get_smoke_config
from repro.core import actor as actor_lib
from repro.core import learner as learner_lib
from repro.core.metrics import EpisodeTracker
from repro.core.queue import LagController
from repro.core.replay import ReplayBuffer, mix_batches
from repro.data.envs import make_env
from repro.models import backbone as bb
from repro.models import common as pcommon


def small_arch(env) -> ArchConfig:
    return get_smoke_config("impala_shallow").replace(image_hw=env.image_hw)


def run_training(env_name: str, icfg: ImpalaConfig, num_envs: int,
                 steps: int, seed: int = 0,
                 arch: Optional[ArchConfig] = None
                 ) -> Tuple[EpisodeTracker, Dict]:
    env = make_env(env_name)
    arch = arch or small_arch(env)
    specs = bb.backbone_specs(arch, env.num_actions)
    params = pcommon.init_params(specs, jax.random.key(seed))
    init_fn, unroll = actor_lib.build_actor(env, arch, icfg, num_envs)
    train_step, opt = learner_lib.build_train_step(arch, icfg,
                                                   env.num_actions)
    train_step = jax.jit(train_step)
    opt_state = opt.init(params)
    carry = init_fn(jax.random.key(seed + 1))
    lag = LagController(icfg.policy_lag, params)
    buf = ReplayBuffer(icfg.replay_capacity, seed=seed,
                       reuse_limit=icfg.replay_reuse,
                       priority=icfg.replay_priority)
    tracker = EpisodeTracker(num_envs)
    metrics: Dict = {}
    for step in range(steps):
        carry, traj = unroll(lag.actor_params(), carry)
        tracker.update(np.asarray(traj["rewards"]),
                       np.asarray(traj["done"]))
        batch = traj
        if icfg.replay_fraction > 0:
            buf.add_batch(traj)
            rep = buf.sample(num_envs)
            batch = mix_batches(traj, rep, icfg.replay_fraction,
                                buffer=buf)
        params, opt_state, metrics = train_step(params, opt_state,
                                                jnp.int32(step), batch)
        lag.on_update(params)
    return tracker, metrics
