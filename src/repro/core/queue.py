"""Host-side trajectory ring buffer + deterministic lag stand-in.

``TrajectoryQueue`` here is a *single-threaded* ring buffer and
``LagController`` replays a parameter history to impose an exact,
reproducible policy lag — the right tools when an experiment needs the
off-policy gap of Fig. E.1 as a controlled variable (lag sweeps,
correction ablations). The real concurrent pipeline — actor threads,
backpressure policies, *measured* lag — lives in ``repro.distributed``.
"""
from __future__ import annotations

import collections
from typing import Any, Deque, List, Optional

import jax

PyTree = Any


class TrajectoryQueue:
    def __init__(self, capacity: int = 16):
        self._q: Deque[PyTree] = collections.deque(maxlen=capacity)
        self.dropped = 0
        self.pushed = 0

    def put(self, traj: PyTree) -> bool:
        """Append; returns True iff ``traj`` is now in the queue (always,
        for this ring — same contract as ``repro.distributed``'s queue).
        A full ring evicts its oldest entry, counted in ``dropped``
        *before* the deque silently discards it."""
        if len(self._q) == self._q.maxlen:
            self.dropped += 1
        self._q.append(traj)
        self.pushed += 1
        return True

    def get(self) -> Optional[PyTree]:
        return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        return len(self._q)


class LagController:
    """Serves actor parameters k learner-updates behind (policy lag)."""

    def __init__(self, lag: int, params: PyTree):
        self.lag = max(0, lag)
        self._hist: Deque[PyTree] = collections.deque(maxlen=self.lag + 1)
        self._hist.append(params)

    def on_update(self, params: PyTree) -> None:
        self._hist.append(params)

    def actor_params(self) -> PyTree:
        return self._hist[0]
