"""Host-side trajectory queue between actors and learner (paper Fig. 1).

In the paper, actors on many machines push trajectories into a queue that
the learner drains. Here the queue is an in-process ring buffer carrying
jax pytrees, plus ``LagController`` — a deterministic stand-in for the
asynchrony: it holds the learner's parameter history and serves actors
the parameters from ``lag`` updates ago, making the off-policy gap of
Fig. E.1 an explicit, reproducible quantity.
"""
from __future__ import annotations

import collections
from typing import Any, Deque, List, Optional

import jax

PyTree = Any


class TrajectoryQueue:
    def __init__(self, capacity: int = 16):
        self._q: Deque[PyTree] = collections.deque(maxlen=capacity)
        self.dropped = 0
        self.pushed = 0

    def put(self, traj: PyTree) -> None:
        if len(self._q) == self._q.maxlen:
            self.dropped += 1
        self._q.append(traj)
        self.pushed += 1

    def get(self) -> Optional[PyTree]:
        return self._q.popleft() if self._q else None

    def __len__(self) -> int:
        return len(self._q)


class LagController:
    """Serves actor parameters k learner-updates behind (policy lag)."""

    def __init__(self, lag: int, params: PyTree):
        self.lag = max(0, lag)
        self._hist: Deque[PyTree] = collections.deque(maxlen=self.lag + 1)
        self._hist.append(params)

    def on_update(self, params: PyTree) -> None:
        self._hist.append(params)

    def actor_params(self) -> PyTree:
        return self._hist[0]
