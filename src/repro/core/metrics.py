"""Evaluation metrics: mean capped human-normalised score (paper §5.3 /
Appendix B) and episode-return accounting from trajectory streams."""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence

import numpy as np


def capped_normalised_score(scores: Sequence[float],
                            human: Sequence[float],
                            random: Sequence[float]) -> float:
    """(1/N) sum_t min(1, (s_t - r_t) / (h_t - r_t)) — Table B.1 footer."""
    vals = []
    for s, h, r in zip(scores, human, random):
        denom = max(h - r, 1e-9)
        vals.append(min(1.0, (s - r) / denom))
    return float(np.mean(vals))


def median_normalised_score(scores, human, random) -> float:
    """Median human-normalised score (Atari-57 protocol, Table 4)."""
    vals = [(s - r) / max(h - r, 1e-9)
            for s, h, r in zip(scores, human, random)]
    return float(np.median(vals))


class EpisodeTracker:
    """Accumulates per-env episode returns from (reward, done) streams."""

    def __init__(self, num_envs: int):
        self.running = np.zeros(num_envs)
        self.completed: List[float] = []

    def update(self, rewards: np.ndarray, dones: np.ndarray) -> None:
        """rewards/dones: (B, T)."""
        rewards = np.asarray(rewards)
        dones = np.asarray(dones)
        for t in range(rewards.shape[1]):
            self.running += rewards[:, t]
            ended = dones[:, t]
            if ended.any():
                self.completed.extend(self.running[ended].tolist())
                self.running[ended] = 0.0

    def mean_return(self, last_n: int = 100) -> float:
        if not self.completed:
            return float("nan")
        return float(np.mean(self.completed[-last_n:]))
