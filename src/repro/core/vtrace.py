"""V-trace (paper §4, Eq. 1) — the off-policy actor-critic correction.

    v_s = V(x_s) + sum_{t=s}^{s+n-1} gamma^{t-s} (prod_{i=s}^{t-1} c_i) delta_t V
    delta_t V = rho_t (r_t + gamma V(x_{t+1}) - V(x_t))
    rho_t = min(rho_bar, pi(a_t|x_t)/mu(a_t|x_t)),  c_i = lambda * min(c_bar, ...)

Computed via the recursion of Remark 1:
    v_s - V(x_s) = delta_s V + gamma_s c_s (v_{s+1} - V(x_{s+1}))

All tensors are batch-major (B, T); ``bootstrap_value`` is V(x_{s+n}) (B,).
Three implementations:
  * ``vtrace_reference``  — O(T^2) literal Eq. (1), the test oracle;
  * ``vtrace_scan``       — reverse ``lax.scan`` (production CPU/TPU path);
  * ``impl='pallas'``     — the Pallas TPU kernel in ``repro.kernels``.

Gradients must not flow through the targets: callers receive
``stop_gradient``-ed ``vs``/``pg_advantages`` (paper §4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class VTraceReturns:
    vs: jax.Array              # (B, T) V-trace value targets
    pg_advantages: jax.Array   # (B, T) rho_s (r_s + gamma v_{s+1} - V(x_s))


def _clipped_weights(log_rhos, rho_bar, c_bar, lambda_):
    rhos = jnp.exp(log_rhos)
    rho_t = jnp.minimum(rho_bar, rhos) if rho_bar is not None else rhos
    c_t = jnp.minimum(c_bar, rhos) if c_bar is not None else rhos
    return rho_t, lambda_ * c_t


def vtrace_scan(log_rhos, discounts, rewards, values, bootstrap_value,
                rho_bar: Optional[float] = 1.0, c_bar: Optional[float] = 1.0,
                lambda_: float = 1.0) -> VTraceReturns:
    """Reverse-scan V-trace. All (B, T) except bootstrap_value (B,)."""
    log_rhos = log_rhos.astype(jnp.float32)
    discounts = discounts.astype(jnp.float32)
    rewards = rewards.astype(jnp.float32)
    values = values.astype(jnp.float32)
    bootstrap_value = bootstrap_value.astype(jnp.float32)

    rho_t, c_t = _clipped_weights(log_rhos, rho_bar, c_bar, lambda_)
    values_tp1 = jnp.concatenate(
        [values[:, 1:], bootstrap_value[:, None]], axis=1)
    deltas = rho_t * (rewards + discounts * values_tp1 - values)

    def body(acc, xs):
        delta, disc, c = xs
        acc = delta + disc * c * acc
        return acc, acc

    xs = (jnp.moveaxis(deltas, 1, 0), jnp.moveaxis(discounts, 1, 0),
          jnp.moveaxis(c_t, 1, 0))
    _, accs = jax.lax.scan(body, jnp.zeros_like(bootstrap_value), xs,
                           reverse=True)
    vs_minus_v = jnp.moveaxis(accs, 0, 1)
    vs = values + vs_minus_v

    vs_tp1 = jnp.concatenate([vs[:, 1:], bootstrap_value[:, None]], axis=1)
    # pg uses its own (possibly different) clipping; paper uses rho_bar too
    pg_adv = rho_t * (rewards + discounts * vs_tp1 - values)
    return VTraceReturns(jax.lax.stop_gradient(vs),
                         jax.lax.stop_gradient(pg_adv))


def vtrace_reference(log_rhos, discounts, rewards, values, bootstrap_value,
                     rho_bar: Optional[float] = 1.0,
                     c_bar: Optional[float] = 1.0,
                     lambda_: float = 1.0) -> VTraceReturns:
    """Literal O(T^2) Eq. (1) — used as the oracle in tests."""
    log_rhos = jnp.asarray(log_rhos, jnp.float32)
    b, t = log_rhos.shape
    rho_t, c_t = _clipped_weights(log_rhos, rho_bar, c_bar, lambda_)
    values = jnp.asarray(values, jnp.float32)
    rewards = jnp.asarray(rewards, jnp.float32)
    discounts = jnp.asarray(discounts, jnp.float32)
    values_tp1 = jnp.concatenate(
        [values[:, 1:], jnp.asarray(bootstrap_value, jnp.float32)[:, None]],
        axis=1)
    deltas = rho_t * (rewards + discounts * values_tp1 - values)

    vs = []
    for s in range(t):
        # direct product form: sum_t gamma^{t-s} (prod c_i) delta_t
        total = jnp.zeros((b,), jnp.float32)
        coef = jnp.ones((b,), jnp.float32)
        for u in range(s, t):
            total = total + coef * deltas[:, u]
            coef = coef * discounts[:, u] * c_t[:, u]
        vs.append(values[:, s] + total)
    vs = jnp.stack(vs, axis=1)
    vs_tp1 = jnp.concatenate(
        [vs[:, 1:], jnp.asarray(bootstrap_value, jnp.float32)[:, None]], axis=1)
    pg_adv = rho_t * (rewards + discounts * vs_tp1 - values)
    return VTraceReturns(vs, pg_adv)


def vtrace(log_rhos, discounts, rewards, values, bootstrap_value,
           rho_bar: Optional[float] = 1.0, c_bar: Optional[float] = 1.0,
           lambda_: float = 1.0, impl: str = "scan") -> VTraceReturns:
    """Dispatching entry point. impl: 'scan' | 'pallas' | 'reference'."""
    if impl == "scan":
        return vtrace_scan(log_rhos, discounts, rewards, values,
                           bootstrap_value, rho_bar, c_bar, lambda_)
    if impl == "reference":
        return vtrace_reference(log_rhos, discounts, rewards, values,
                                bootstrap_value, rho_bar, c_bar, lambda_)
    if impl == "pallas":
        from repro.kernels import ops
        vs, pg = ops.vtrace(log_rhos, discounts, rewards, values,
                            bootstrap_value, rho_bar=rho_bar, c_bar=c_bar,
                            lambda_=lambda_)
        return VTraceReturns(jax.lax.stop_gradient(vs),
                             jax.lax.stop_gradient(pg))
    raise ValueError(impl)


def vtrace_from_logits(behaviour_logprob, target_logits, actions, discounts,
                       rewards, values, bootstrap_value,
                       rho_bar: Optional[float] = 1.0,
                       c_bar: Optional[float] = 1.0,
                       lambda_: float = 1.0,
                       impl: str = "scan") -> VTraceReturns:
    """Compute log importance ratios from the learner's logits and the
    behaviour log-probability shipped in the trajectory (the actor sends
    mu(a_t|x_t) with each trajectory — paper §3)."""
    target_logprob = action_log_probs(target_logits, actions)
    log_rhos = target_logprob - behaviour_logprob
    return vtrace(log_rhos, discounts, rewards, values, bootstrap_value,
                  rho_bar, c_bar, lambda_, impl=impl)


def action_log_probs(logits, actions):
    """logits (B,T,A) f32, actions (B,T) int32 -> (B,T) log pi(a|x)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(logp, actions[..., None], axis=-1)[..., 0]
