"""The IMPALA learner: batched V-trace actor-critic updates (paper §3, §4.2).

``build_train_step`` closes over the architecture + IMPALA configs and the
optimizer and returns a pure ``train_step(params, opt_state, step, batch)``
suitable for ``jax.jit`` with pjit shardings (see ``repro.launch``). The
same builder serves the CPU examples and the 512-device dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ImpalaConfig
from repro.core import losses as losses_lib
from repro.models import backbone as bb
from repro.models.common import cast as common_cast
from repro.optim import optimizer as opt_lib

PyTree = Any


def forward_trajectory(params, batch: Dict, arch_cfg: ArchConfig,
                       num_actions: int):
    """Run the backbone over the T+1 trajectory observations.

    Returns (logits (B,T+1,A), values (B,T+1), aux)."""
    if arch_cfg.family == "impala_cnn":
        model_batch = {
            "image": batch["obs_image"],
            "last_action": batch["last_action"],
            "last_reward": batch["last_reward"],
            "done": batch["done_in"],
            "lstm_state": batch.get("lstm_state"),
        }
    else:
        model_batch = {"tokens": batch["obs_token"]}
        for k in ("enc_embed", "image_embed"):
            if k in batch:
                model_batch[k] = batch[k]
    out = bb.apply_train(params, model_batch, arch_cfg, num_actions)
    return out.policy_logits, out.values, out.aux_loss


def build_loss_fn(arch_cfg: ArchConfig, cfg: ImpalaConfig,
                  num_actions: int, vtrace_impl: str = "auto",
                  aux_coef: float = 0.01):
    def loss_fn(params, batch):
        logits, values, aux = forward_trajectory(params, batch, arch_cfg,
                                                 num_actions)
        loss_batch = {
            "actions": batch["actions"],
            "rewards": batch["rewards"],
            "discounts": batch["discounts"],
            "behaviour_logprob": batch["behaviour_logprob"],
            "bootstrap_value": values[:, -1],
        }
        total, metrics = losses_lib.impala_loss(
            cfg, logits[:, :-1], values[:, :-1], loss_batch,
            impl=vtrace_impl)
        if arch_cfg.moe is not None:
            total = total + aux_coef * aux * (
                batch["actions"].shape[0] * batch["actions"].shape[1])
            metrics["loss/moe_aux"] = aux
        return total, metrics

    return loss_fn


def build_train_step(arch_cfg: ArchConfig, cfg: ImpalaConfig,
                     num_actions: int,
                     optimizer: opt_lib.Optimizer = None,
                     vtrace_impl: str = "auto",
                     mixed_precision: bool = False,
                     ) -> Callable[..., Tuple[PyTree, PyTree, Dict]]:
    """vtrace_impl: 'auto' picks the Pallas kernel on TPU and the scan
    path elsewhere (``losses.resolve_vtrace_impl``); 'scan' / 'pallas' /
    'reference' pin an implementation.

    mixed_precision: the *live* params are bf16 leaves and the f32
    master copy lives in the optimizer state — so the autodiff cotangents
    (and the cross-device gradient reduction GSPMD inserts on them) are
    bf16, halving grad-sync bytes (§Perf B2). RMSProp accumulates on the
    f32 master. Note: casting to bf16 *inside* the step does NOT work —
    GSPMD places the reduction after the upcast (measured, §Perf B2).

    In this mode train_step expects ``params`` bf16 and
    ``opt_state = {"opt": <optimizer state>, "master": <f32 params>}``.
    """
    if optimizer is None:
        optimizer = opt_lib.rmsprop(decay=cfg.rmsprop_decay,
                                    eps=cfg.rmsprop_eps,
                                    momentum=cfg.rmsprop_momentum)
    lr_fn = opt_lib.linear_schedule(cfg.learning_rate, 0.0,
                                    cfg.lr_anneal_steps)
    loss_fn = build_loss_fn(arch_cfg, cfg, num_actions, vtrace_impl)

    def train_step(params, opt_state, step, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        lr = lr_fn(step)
        if mixed_precision:
            grads = common_cast(grads, jnp.float32)
            grads, grad_norm = opt_lib.clip_by_global_norm(
                grads, cfg.grad_clip_norm)
            master = opt_state["master"]
            updates, inner = optimizer.update(grads, opt_state["opt"],
                                              master, lr)
            master = opt_lib.apply_updates(master, updates)
            params = common_cast(master, jnp.bfloat16)
            opt_state = {"opt": inner, "master": master}
        else:
            grads, grad_norm = opt_lib.clip_by_global_norm(
                grads, cfg.grad_clip_norm)
            updates, opt_state = optimizer.update(grads, opt_state, params,
                                                  lr)
            params = opt_lib.apply_updates(params, updates)
        metrics["opt/grad_norm"] = grad_norm
        metrics["opt/lr"] = lr
        return params, opt_state, metrics

    return train_step, optimizer


def build_replay_loss_fn(arch_cfg: ArchConfig, cfg: ImpalaConfig,
                         num_actions: int, vtrace_impl: str = "auto",
                         aux_coef: float = 0.01):
    """Replay-aware loss: ``loss_fn(params, target_params, batch)``.

    ``batch['replay_mask']`` (B,) flags replayed rows. The IMPACT
    recipe: replayed rows take the *target network's* values as the
    V-trace correction baseline (``corrections.replay_baseline_mix``),
    so K repeated consumptions chase a fixed target; online rows are
    the exact standard loss. The per-trajectory |pg advantage| metric
    (``vtrace/traj_adv_mag``) doubles as the replay priority signal.
    """
    from repro.core import corrections

    def loss_fn(params, target_params, batch):
        logits, values, aux = forward_trajectory(params, batch, arch_cfg,
                                                 num_actions)
        _, tvalues, _ = forward_trajectory(target_params, batch, arch_cfg,
                                           num_actions)
        mask = batch["replay_mask"]
        corr_values = corrections.replay_baseline_mix(
            values[:, :-1], tvalues[:, :-1], mask)
        corr_bootstrap = corrections.replay_baseline_mix(
            values[:, -1], tvalues[:, -1], mask)
        loss_batch = {
            "actions": batch["actions"],
            "rewards": batch["rewards"],
            "discounts": batch["discounts"],
            "behaviour_logprob": batch["behaviour_logprob"],
            "bootstrap_value": values[:, -1],
        }
        total, metrics = losses_lib.impala_loss(
            cfg, logits[:, :-1], values[:, :-1], loss_batch,
            impl=vtrace_impl, corr_values=corr_values,
            corr_bootstrap=corr_bootstrap, per_traj=True)
        if arch_cfg.moe is not None:
            total = total + aux_coef * aux * (
                batch["actions"].shape[0] * batch["actions"].shape[1])
            metrics["loss/moe_aux"] = aux
        return total, metrics

    return loss_fn


def build_replay_train_step(arch_cfg: ArchConfig, cfg: ImpalaConfig,
                            num_actions: int,
                            optimizer: opt_lib.Optimizer = None,
                            vtrace_impl: str = "auto",
                            ) -> Callable[..., Tuple[PyTree, PyTree, Dict]]:
    """``train_step(params, target_params, opt_state, step, batch)`` —
    the fused update for the replay path. Gradients flow only through
    ``params`` (argnum 0); ``target_params`` is a read-only periodic
    snapshot, so callers jit with ``donate_argnums=(0, 2)`` and keep
    the target buffer alive across steps."""
    if optimizer is None:
        optimizer = opt_lib.rmsprop(decay=cfg.rmsprop_decay,
                                    eps=cfg.rmsprop_eps,
                                    momentum=cfg.rmsprop_momentum)
    lr_fn = opt_lib.linear_schedule(cfg.learning_rate, 0.0,
                                    cfg.lr_anneal_steps)
    loss_fn = build_replay_loss_fn(arch_cfg, cfg, num_actions, vtrace_impl)

    def train_step(params, target_params, opt_state, step, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, target_params, batch)
        grads, grad_norm = opt_lib.clip_by_global_norm(
            grads, cfg.grad_clip_norm)
        lr = lr_fn(step)
        updates, opt_state = optimizer.update(grads, opt_state, params, lr)
        params = opt_lib.apply_updates(params, updates)
        metrics["opt/grad_norm"] = grad_norm
        metrics["opt/lr"] = lr
        return params, opt_state, metrics

    return train_step, optimizer


def build_replay_grad_apply_steps(arch_cfg: ArchConfig, cfg: ImpalaConfig,
                                  num_actions: int,
                                  optimizer: opt_lib.Optimizer = None,
                                  vtrace_impl: str = "auto"):
    """Replay-aware split of ``build_grad_apply_steps``:
    ``grad_step(params, target_params, batch)`` plus the unchanged
    ``apply_step`` (clipping on the exchanged mean, identical update
    math so group replicas stay digest-identical)."""
    if optimizer is None:
        optimizer = opt_lib.rmsprop(decay=cfg.rmsprop_decay,
                                    eps=cfg.rmsprop_eps,
                                    momentum=cfg.rmsprop_momentum)
    lr_fn = opt_lib.linear_schedule(cfg.learning_rate, 0.0,
                                    cfg.lr_anneal_steps)
    loss_fn = build_replay_loss_fn(arch_cfg, cfg, num_actions, vtrace_impl)

    def grad_step(params, target_params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, target_params, batch)
        return grads, metrics

    def apply_step(params, opt_state, step, grads):
        grads, grad_norm = opt_lib.clip_by_global_norm(
            grads, cfg.grad_clip_norm)
        lr = lr_fn(step)
        updates, opt_state = optimizer.update(grads, opt_state, params,
                                              lr)
        params = opt_lib.apply_updates(params, updates)
        return params, opt_state, {"opt/grad_norm": grad_norm,
                                   "opt/lr": lr}

    return grad_step, apply_step, optimizer


def build_grad_apply_steps(arch_cfg: ArchConfig, cfg: ImpalaConfig,
                           num_actions: int,
                           optimizer: opt_lib.Optimizer = None,
                           vtrace_impl: str = "auto"):
    """``train_step`` split at the gradient: ``grad_step(params, batch)
    -> (grads, metrics)`` and ``apply_step(params, opt_state, step,
    grads) -> (params, opt_state, metrics)`` — the shape a
    data-parallel learner group needs, with a gradient exchange (mean
    over the group) between the two halves.

    Clipping happens in ``apply_step``, i.e. on the *exchanged mean*:
    clip-after-average is the data-parallel-faithful choice (it equals
    clipping the global-batch gradient a single learner with the
    concatenated batch would have computed, up to the averaging
    order), and it keeps every replica applying bit-identical updates
    because they all clip the same broadcast buffer.

    Composing the halves locally (``apply_step(params, opt_state, step,
    grad_step(params, batch)[0])``) is mathematically the fused
    ``train_step``; the fused path stays the single-learner default
    because one jit program fuses better than two.
    """
    if optimizer is None:
        optimizer = opt_lib.rmsprop(decay=cfg.rmsprop_decay,
                                    eps=cfg.rmsprop_eps,
                                    momentum=cfg.rmsprop_momentum)
    lr_fn = opt_lib.linear_schedule(cfg.learning_rate, 0.0,
                                    cfg.lr_anneal_steps)
    loss_fn = build_loss_fn(arch_cfg, cfg, num_actions, vtrace_impl)

    def grad_step(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def apply_step(params, opt_state, step, grads):
        grads, grad_norm = opt_lib.clip_by_global_norm(
            grads, cfg.grad_clip_norm)
        lr = lr_fn(step)
        updates, opt_state = optimizer.update(grads, opt_state, params,
                                              lr)
        params = opt_lib.apply_updates(params, updates)
        return params, opt_state, {"opt/grad_norm": grad_norm,
                                   "opt/lr": lr}

    return grad_step, apply_step, optimizer


def build_spmd_train_step(arch_cfg: ArchConfig, cfg: ImpalaConfig,
                          num_actions: int, mesh,
                          optimizer: opt_lib.Optimizer = None,
                          vtrace_impl: str = "auto",
                          batch_replicated: bool = False,
                          ) -> Callable[..., Tuple[PyTree, PyTree, Dict]]:
    """Single-process data-parallel ``train_step`` over a ``('data',)``
    mesh: ``shard_map`` shards the batch on the leading trajectory axis,
    every device runs the backward pass on its shard, and the gradients
    are mean-reduced in-XLA (``lax.pmean`` — one fused collective, no
    host round-trip) before the replicated clip/update.

    Clip-after-average matches ``build_grad_apply_steps``: with N
    devices and per-shard sum-losses, the applied update is exactly
    what an N-learner hub/spoke group computes from the same shards —
    bit-identical on CPU, pinned by the digest-triangle test. Scalar
    metrics are pmean'd (each shard's loss is a local sum, so the
    reported loss is the per-shard mean, like a group member's).

    ``batch_replicated=True`` builds the divisibility-fallback variant
    (``sharding/rules.py`` replicates a leading dim the mesh cannot
    split): every device sees the full batch, the pmean is an identity
    over identical gradients, and the update equals the single-device
    fused step. Callers jit the result with ``donate_argnums=(0, 1)``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if optimizer is None:
        optimizer = opt_lib.rmsprop(decay=cfg.rmsprop_decay,
                                    eps=cfg.rmsprop_eps,
                                    momentum=cfg.rmsprop_momentum)
    lr_fn = opt_lib.linear_schedule(cfg.learning_rate, 0.0,
                                    cfg.lr_anneal_steps)
    loss_fn = build_loss_fn(arch_cfg, cfg, num_actions, vtrace_impl)

    def local_step(params, opt_state, step, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads = jax.lax.pmean(grads, "data")
        metrics = jax.lax.pmean(metrics, "data")
        grads, grad_norm = opt_lib.clip_by_global_norm(
            grads, cfg.grad_clip_norm)
        lr = lr_fn(step)
        updates, opt_state = optimizer.update(grads, opt_state, params,
                                              lr)
        params = opt_lib.apply_updates(params, updates)
        metrics["opt/grad_norm"] = grad_norm
        metrics["opt/lr"] = lr
        return params, opt_state, metrics

    bspec = P() if batch_replicated else P("data")
    train_step = shard_map(local_step, mesh=mesh,
                           in_specs=(P(), P(), P(), bspec),
                           out_specs=(P(), P(), P()))
    return train_step, optimizer


def build_spmd_replay_train_step(arch_cfg: ArchConfig, cfg: ImpalaConfig,
                                 num_actions: int, mesh,
                                 optimizer: opt_lib.Optimizer = None,
                                 vtrace_impl: str = "auto",
                                 batch_replicated: bool = False,
                                 ) -> Callable[..., Tuple[PyTree, PyTree,
                                                          Dict]]:
    """SPMD variant of ``build_replay_train_step``:
    ``train_step(params, target_params, opt_state, step, batch)`` with
    the batch (``replay_mask`` included — it is per-row data, so it
    shards with the rows) split over the ``('data',)`` mesh and the
    gradients pmean'd in-XLA. The per-trajectory ``vtrace/traj_adv_mag``
    metric is (B,)-shaped: each shard emits its local rows and the
    shard_map output spec reassembles the global vector, so replay
    re-prioritization sees every trajectory. Callers jit with
    ``donate_argnums=(0, 2)`` (the target is a long-lived snapshot)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if optimizer is None:
        optimizer = opt_lib.rmsprop(decay=cfg.rmsprop_decay,
                                    eps=cfg.rmsprop_eps,
                                    momentum=cfg.rmsprop_momentum)
    lr_fn = opt_lib.linear_schedule(cfg.learning_rate, 0.0,
                                    cfg.lr_anneal_steps)
    loss_fn = build_replay_loss_fn(arch_cfg, cfg, num_actions, vtrace_impl)

    def local_step(params, target_params, opt_state, step, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, target_params, batch)
        metrics = dict(metrics)
        traj_adv = metrics.pop("vtrace/traj_adv_mag")
        grads = jax.lax.pmean(grads, "data")
        metrics = jax.lax.pmean(metrics, "data")
        grads, grad_norm = opt_lib.clip_by_global_norm(
            grads, cfg.grad_clip_norm)
        lr = lr_fn(step)
        updates, opt_state = optimizer.update(grads, opt_state, params,
                                              lr)
        params = opt_lib.apply_updates(params, updates)
        metrics["opt/grad_norm"] = grad_norm
        metrics["opt/lr"] = lr
        return params, opt_state, metrics, traj_adv

    bspec = P() if batch_replicated else P("data")
    smapped = shard_map(local_step, mesh=mesh,
                        in_specs=(P(), P(), P(), P(), bspec),
                        out_specs=(P(), P(), P(), bspec))

    def train_step(params, target_params, opt_state, step, batch):
        params, opt_state, metrics, traj_adv = smapped(
            params, target_params, opt_state, step, batch)
        metrics = dict(metrics)
        metrics["vtrace/traj_adv_mag"] = traj_adv
        return params, opt_state, metrics

    return train_step, optimizer


def opt_state_specs(param_specs: PyTree, cfg: ImpalaConfig,
                    mixed_precision: bool = False) -> PyTree:
    """Spec tree for the optimizer state (mirrors param specs)."""
    inner = ({"ms": param_specs, "mom": param_specs}
             if cfg.rmsprop_momentum else {"ms": param_specs})
    if mixed_precision:
        return {"opt": inner, "master": param_specs}
    return inner
