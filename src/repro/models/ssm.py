"""Mamba-2 (state-space duality, arXiv:2405.21060) block.

Chunked SSD: within a chunk the quadratic "attention" form, across chunks
a diagonal linear recurrence on the (H, P, N) state. The cross-chunk state
pass is the sequential hot spot targeted by ``kernels/linear_scan.py``;
the reference path below carries it through a ``lax.scan``.

Layouts: x (B, T, H, P); B/C (B, T, N) (single group); state (B, H, P, N).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Spec, dense, dense_specs, rmsnorm, rmsnorm_specs
from repro.sharding.rules import lc


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    heads = s.num_heads or d_inner // s.head_dim
    return d_inner, heads, s.head_dim, s.state_dim


def ssm_specs(cfg: ArchConfig) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, h, p, n = _dims(cfg)
    conv_ch = d_inner + 2 * n  # conv over x, B, C as in mamba2
    return {
        "in_zx": dense_specs((d,), (2 * d_inner,), ("embed",), ("ff",)),
        "in_bc": dense_specs((d,), (2 * n,), ("embed",), (None,)),
        "in_dt": dense_specs((d,), (h,), ("embed",), ("ssm_heads",)),
        "conv": {"kernel": Spec((s.conv_width, conv_ch), ("conv", "ff"),
                                init="normal"),
                 "bias": Spec((conv_ch,), ("ff",), init="zeros")},
        "dt_bias": {"w": Spec((h,), ("ssm_heads",), init="zeros")},
        "a_log": {"w": Spec((h,), ("ssm_heads",), init="ones")},
        "d_skip": {"w": Spec((h,), ("ssm_heads",), init="ones")},
        "out_norm": rmsnorm_specs(d_inner, "ff"),
        "out": dense_specs((d_inner,), (d,), ("ff",), ("embed",)),
    }


def _causal_conv(x, kernel, bias, state: Optional[jax.Array] = None):
    """Depthwise causal conv. x:(B,T,C) kernel:(W,C). If state (B,W-1,C) is
    given, runs in streaming mode and returns (y, new_state)."""
    w = kernel.shape[0]
    if state is not None:
        xin = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xin[:, -(w - 1):]
    else:
        xin = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
        new_state = None
    y = sum(xin[:, i:i + x.shape[1]] * kernel[i].astype(x.dtype)
            for i in range(w))
    y = y + bias.astype(x.dtype)
    return jax.nn.silu(y), new_state


def ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x: (B,T,H,P) f32; dt: (B,T,H) f32 (softplus'ed); a_log: (H,) (A = -exp);
    b, c: (B,T,N) f32; d_skip: (H,).
    Returns (y (B,T,H,P), final_state (B,H,P,N)).
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    tt = t + pad
    nc = tt // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))          # (H,) negative
    log_a = dt * a                                    # (B,T,H) <= 0
    xdt = x * dt[..., None]

    # reshape to chunks, scan sequentially carrying state
    def r(z):
        return z.reshape((bsz, nc, chunk) + z.shape[2:])
    xc, dtc, bc_, cc, lac = map(r, (xdt, dt, b, c, log_a))

    state0 = (jnp.zeros((bsz, h, p, n), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))

    def body(state, inp):
        xk, bk, ck, lak = inp      # (B,L,H,P) (B,L,N) (B,L,N) (B,L,H)
        cum = jnp.cumsum(lak, axis=1)                   # (B,L,H)
        # intra-chunk: y_i += sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) x_j
        scores = jnp.einsum("bin,bjn->bij", ck, bk)     # (B,L,L)
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B,i,j,H)
        l = xk.shape[1]
        mask = jnp.tril(jnp.ones((l, l), bool))
        gamma = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", scores, gamma, xk)
        # inter-chunk: y_i += C_i . (exp(cum_i) * state)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", ck, state, jnp.exp(cum))
        # state update: state' = exp(cum_L) state + sum_j exp(cum_L - cum_j) x_j B_j
        seg = jnp.exp(cum[:, -1:, :] - cum)             # (B,L,H)
        new_state = (jnp.exp(cum[:, -1])[:, :, None, None] * state
                     + jnp.einsum("bjhp,bjn,bjh->bhpn", xk, bk, seg))
        return new_state, y_intra + y_inter

    final_state, yc = jax.lax.scan(body, state0,
                                   tuple(jnp.moveaxis(z, 1, 0)
                                         for z in (xc, bc_, cc, lac)))
    y = jnp.moveaxis(yc, 0, 1).reshape(bsz, tt, h, p)[:, :t]
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * x[:, :t]
    return y, final_state


def ssd_step(state, x, dt, a_log, b, c, d_skip):
    """Single decode step. x:(B,H,P) dt:(B,H) b/c:(B,N). Returns (y, state')."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    la = dt * a                                        # (B,H)
    decay = jnp.exp(la)[:, :, None, None]
    xdt = x * dt[..., None]
    new_state = decay * state + jnp.einsum("bhp,bn->bhpn", xdt, b)
    y = jnp.einsum("bhpn,bn->bhp", new_state, c)
    y = y + d_skip[None, :, None] * x
    return y, new_state


def apply_ssm(params, x, cfg: ArchConfig, *, mode: str = "train",
              state: Optional[Dict[str, jax.Array]] = None,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B,T,d_model). state = {'ssm': (B,H,P,N), 'conv': (B,W-1,C)}."""
    dtype = jnp.dtype(cfg.dtype)
    s = cfg.ssm
    d_inner, h, p, n = _dims(cfg)
    bsz, t, _ = x.shape

    zx = dense(params["in_zx"], x, dtype=dtype)
    z, xi = zx[..., :d_inner], zx[..., d_inner:]
    bc = dense(params["in_bc"], x, dtype=dtype)
    dt_raw = dense(params["in_dt"], x, dtype=dtype)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"]["w"].astype(jnp.float32))

    conv_in = jnp.concatenate([xi, bc], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv_state = _causal_conv(
        conv_in, params["conv"]["kernel"], params["conv"]["bias"], conv_state)
    xi = conv_out[..., :d_inner]
    b_ = conv_out[..., d_inner:d_inner + n].astype(jnp.float32)
    c_ = conv_out[..., d_inner + n:].astype(jnp.float32)

    xh = xi.reshape(bsz, t, h, p).astype(jnp.float32)
    xh = lc(xh, ("batch", "seq", "ssm_heads", None))

    if mode == "decode":
        assert state is not None and t == 1
        y, new_ssm = ssd_step(state["ssm"].astype(jnp.float32),
                              xh[:, 0], dt[:, 0], params["a_log"]["w"],
                              b_[:, 0], c_[:, 0], params["d_skip"]["w"])
        y = y[:, None]
        new_state = {"ssm": new_ssm, "conv": new_conv_state}
    else:
        init = state["ssm"].astype(jnp.float32) if state is not None else None
        y, final = ssd_chunked(xh, dt, params["a_log"]["w"], b_, c_,
                               params["d_skip"]["w"], s.chunk_size, init)
        new_state = ({"ssm": final, "conv": new_conv_state}
                     if mode == "prefill" else None)
        if mode == "prefill" and new_conv_state is None:
            # build streaming conv state from the raw tail of the inputs
            w = s.conv_width
            tail = conv_in[:, -(w - 1):]
            if tail.shape[1] < w - 1:
                tail = jnp.pad(tail, ((0, 0), (w - 1 - tail.shape[1], 0), (0, 0)))
            new_state["conv"] = tail

    y = y.reshape(bsz, t, d_inner).astype(dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["out_norm"], y)
    y = lc(y, ("batch", "seq", "ff"))
    out = dense(params["out"], y, dtype=dtype)
    return lc(out, ("batch", "seq", "embed")), new_state


def ssm_state_abstract(batch: int, cfg: ArchConfig, dtype):
    s = cfg.ssm
    d_inner, h, p, n = _dims(cfg)
    conv_ch = d_inner + 2 * n
    return {
        "ssm": jax.ShapeDtypeStruct((batch, h, p, n), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, s.conv_width - 1, conv_ch), dtype),
    }


def ssm_state_init(batch: int, cfg: ArchConfig, dtype):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        ssm_state_abstract(batch, cfg, dtype),
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
