"""Attention: MHA/GQA/MQA with RoPE, sliding windows, chunked online-softmax
for long sequences, cross-attention, and KV-cache decode.

Layouts:
  activations  (B, T, d_model)
  q            (B, T, H, Dh)
  k/v          (B, T, K, Dh)          K = num_kv_heads, group G = H // K
  kv cache     (B, S_cache, K, Dh)    ring buffer when sliding window
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.common import Spec, dense, dense_specs, rope
from repro.sharding.rules import lc

NEG_INF = -1e30

# Dense (materialized-scores) attention is used up to this many kv positions;
# beyond it the chunked online-softmax path keeps memory bounded.
DENSE_SEQ_THRESHOLD = 4096
Q_CHUNK = 512
KV_CHUNK = 1024


# ---------------------------------------------------------------------------
# Specs


def attention_specs(cfg: ArchConfig, cross: bool = False) -> Dict[str, Dict[str, Spec]]:
    d, h, k, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    bias = cfg.qkv_bias
    return {
        "q": dense_specs((d,), (h, dh), ("embed",), ("heads", "head_dim"), bias=bias),
        "k": dense_specs((d,), (k, dh), ("embed",), ("kv_heads", "head_dim"), bias=bias),
        "v": dense_specs((d,), (k, dh), ("embed",), ("kv_heads", "head_dim"), bias=bias),
        "o": dense_specs((h, dh), (d,), ("heads", "head_dim"), ("embed",)),
    }


# ---------------------------------------------------------------------------
# Core softmax-attention math


def _dense_attention(q, k, v, mask, scale):
    """q:(B,Tq,H,D) k/v:(B,Tk,K,D) mask:(B,1,1,Tq,Tk) or broadcastable."""
    b, tq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, tq, kh, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, tq, h, d)


def _chunked_causal_attention(q, k, v, q_positions, kv_positions, scale,
                              window: int = 0,
                              q_chunk: int = Q_CHUNK, kv_chunk: int = KV_CHUNK):
    """Online-softmax attention, O(q_chunk * kv_chunk) live scores.

    Causal w.r.t. absolute positions; optional sliding window.
    q:(B,Tq,H,D)  k/v:(B,Tk,K,D)  *_positions:(B,T*) absolute indices.
    """
    b, tq, h, d = q.shape
    tk = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    q_chunk = min(q_chunk, tq)
    kv_chunk = min(kv_chunk, tk)
    # pad to multiples
    def pad_to(x, mult, axis):
        rem = (-x.shape[axis]) % mult
        if rem == 0:
            return x
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, rem)
        return jnp.pad(x, pads)

    qp = pad_to(q, q_chunk, 1)
    qpos = pad_to(q_positions, q_chunk, 1)
    kp = pad_to(k, kv_chunk, 1)
    vp = pad_to(v, kv_chunk, 1)
    kpos = pad_to(kv_positions + 1, kv_chunk, 1) - 1  # padded keys -> pos -1
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk

    qp = qp.reshape(b, nq, q_chunk, kh, g, d)
    kp = kp.reshape(b, nk, kv_chunk, kh, d)
    vp = vp.reshape(b, nk, kv_chunk, kh, d)
    qpos = qpos.reshape(b, nq, q_chunk)
    kpos = kpos.reshape(b, nk, kv_chunk)

    def per_qchunk(qi, qc, qcpos):
        # qc: (B, q_chunk, K, G, D); scan over kv chunks with online softmax
        m0 = jnp.full((b, kh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_chunk), jnp.float32)
        acc0 = jnp.zeros((b, q_chunk, kh, g, d), jnp.float32)

        def body(carry, inp):
            m, l, acc = carry
            kc, vc, kcpos = inp  # (B, kv_chunk, K, D), ..., (B, kv_chunk)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc).astype(jnp.float32) * scale
            valid = kcpos[:, None, None, None, :] <= qcpos[:, None, None, :, None]
            valid &= kcpos[:, None, None, None, :] >= 0
            if window:
                valid &= kcpos[:, None, None, None, :] > (
                    qcpos[:, None, None, :, None] - window)
            s = jnp.where(valid, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(qc.dtype), vc)
            acc_new = acc * jnp.moveaxis(corr, 3, 1)[..., None] + pv
            return (m_new, l_new, acc_new), None

        kvs = (jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0),
               jnp.moveaxis(kpos, 1, 0))
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), kvs)
        l = jnp.maximum(l, 1e-30)
        out = acc / jnp.moveaxis(l, 3, 1)[..., None]
        return out.astype(q.dtype)

    outs = jax.lax.map(
        lambda args: per_qchunk(*args),
        (jnp.arange(nq), jnp.moveaxis(qp, 1, 0), jnp.moveaxis(qpos, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_chunk, h, d)
    return out[:, :tq]


# ---------------------------------------------------------------------------
# Caches


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Static description of a layer's KV cache."""
    length: int          # S_cache (== window for sliding-window archs)
    kv_heads: int
    head_dim: int


def init_cache_arrays(batch: int, spec: CacheSpec, dtype) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((batch, spec.length, spec.kv_heads, spec.head_dim), dtype),
        "v": jnp.zeros((batch, spec.length, spec.kv_heads, spec.head_dim), dtype),
    }


def cache_abstract(batch: int, spec: CacheSpec, dtype) -> Dict[str, jax.ShapeDtypeStruct]:
    shape = (batch, spec.length, spec.kv_heads, spec.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


# ---------------------------------------------------------------------------
# Public apply


def apply_attention(params, x, positions, cfg: ArchConfig, *,
                    causal: bool = True,
                    window: int = 0,
                    mode: str = "train",
                    cache: Optional[Dict[str, jax.Array]] = None,
                    cache_index: Optional[jax.Array] = None,
                    kv_x: Optional[jax.Array] = None,
                    kv_positions: Optional[jax.Array] = None,
                    use_rope: Optional[bool] = None,
                    ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self- or cross-attention.

    mode: 'train'/'prefill' (full sequence) or 'decode' (T==1, uses cache).
    For cross-attention pass kv_x (encoder output); cache then holds the
    projected encoder k/v ('decode' reuses them without recompute).
    Returns (output (B,T,d_model), new_cache or None).
    """
    dtype = jnp.dtype(cfg.dtype)
    dh = cfg.resolved_head_dim
    scale = dh ** -0.5
    use_rope = cfg.use_rope if use_rope is None else use_rope

    q = dense(params["q"], x, dtype=dtype)
    q = lc(q, ("batch", "seq", "heads", "head_dim"))
    if use_rope and not (kv_x is not None):
        q = rope(q, positions, cfg.rope_theta)

    new_cache = None
    if kv_x is not None:
        # cross-attention: keys/values from encoder output, no causal mask
        k = dense(params["k"], kv_x, dtype=dtype)
        v = dense(params["v"], kv_x, dtype=dtype)
        k = lc(k, ("batch", "kv_seq", "kv_heads", "head_dim"))
        v = lc(v, ("batch", "kv_seq", "kv_heads", "head_dim"))
        b, tq = q.shape[0], q.shape[1]
        mask = jnp.ones((b, 1, 1, tq, k.shape[1]), bool)
        out = _dense_attention(q, k, v, mask, scale)
    elif mode == "decode":
        assert cache is not None and cache_index is not None
        # x is (B, 1, d)
        k_new = dense(params["k"], x, dtype=dtype)
        v_new = dense(params["v"], x, dtype=dtype)
        if use_rope:
            k_new = rope(k_new, positions, cfg.rope_theta)
        s_cache = cache["k"].shape[1]
        slot = (cache_index % s_cache) if window else jnp.minimum(
            cache_index, s_cache - 1)
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype),
            (jnp.zeros((), jnp.int32), slot.astype(jnp.int32),
             jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype),
            (jnp.zeros((), jnp.int32), slot.astype(jnp.int32),
             jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)))
        new_cache = {"k": k, "v": v}
        k = lc(k, ("batch", "kv_seq", "kv_heads", "head_dim"))
        v = lc(v, ("batch", "kv_seq", "kv_heads", "head_dim"))
        # positions of cache slots
        if window:
            # ring buffer: slot i holds absolute position
            #   p = idx - ((idx - i) mod S)  where idx = cache_index
            slots = jnp.arange(s_cache)
            kv_pos = cache_index - ((cache_index - slots) % s_cache)
            valid = kv_pos >= jnp.maximum(cache_index - s_cache + 1, 0)
        else:
            kv_pos = jnp.arange(s_cache)
            valid = kv_pos <= cache_index
        if window:
            valid &= kv_pos > (cache_index - window)
        b = q.shape[0]
        mask = jnp.broadcast_to(valid[None, None, None, None, :],
                                (b, 1, 1, 1, s_cache))
        out = _dense_attention(q, k, v, mask, scale)
    else:
        # train / prefill over the full sequence
        k = dense(params["k"], x, dtype=dtype)
        v = dense(params["v"], x, dtype=dtype)
        if use_rope:
            k = rope(k, positions, cfg.rope_theta)
        k = lc(k, ("batch", "seq", "kv_heads", "head_dim"))
        v = lc(v, ("batch", "seq", "kv_heads", "head_dim"))
        b, t = x.shape[0], x.shape[1]
        if mode == "prefill":
            # keep (possibly windowed) kv for subsequent decode
            s_cache = min(window, t) if window else t
            new_cache = {"k": k[:, -s_cache:], "v": v[:, -s_cache:]}
        if t <= DENSE_SEQ_THRESHOLD:
            qpos = positions
            kpos = positions
            mask = kpos[:, None, None, None, :] <= qpos[:, None, None, :, None]
            if window:
                mask &= kpos[:, None, None, None, :] > (
                    qpos[:, None, None, :, None] - window)
            if not causal:
                mask = jnp.ones_like(mask)
            out = _dense_attention(q, k, v, mask, scale)
        else:
            if not causal:
                # long bidirectional: fall back to chunked with no causal mask
                # (not used by assigned archs; encoder seqs are short)
                mask = jnp.ones((b, 1, 1, t, t), bool)
                out = _dense_attention(q, k, v, mask, scale)
            else:
                out = _chunked_causal_attention(
                    q, k, v, positions, positions, scale, window=window)

    out = lc(out, ("batch", "seq", "heads", "head_dim"))
    y = dense(params["o"], out, contract=2, dtype=dtype)
    y = lc(y, ("batch", "seq", "embed"))
    return y, new_cache


def precompute_cross_cache(params, enc_out, cfg: ArchConfig):
    """Project encoder output to k/v once for decode-time cross-attention."""
    dtype = jnp.dtype(cfg.dtype)
    k = dense(params["k"], enc_out, dtype=dtype)
    v = dense(params["v"], enc_out, dtype=dtype)
    return {"k": k, "v": v}


def apply_cross_attention_cached(params, x, cross_cache, cfg: ArchConfig):
    """Decode-time cross-attention against precomputed encoder k/v."""
    dtype = jnp.dtype(cfg.dtype)
    dh = cfg.resolved_head_dim
    q = dense(params["q"], x, dtype=dtype)
    k, v = cross_cache["k"], cross_cache["v"]
    b, tq = q.shape[0], q.shape[1]
    mask = jnp.ones((b, 1, 1, tq, k.shape[1]), bool)
    out = _dense_attention(q, k, v, mask, dh ** -0.5)
    return dense(params["o"], out, contract=2, dtype=dtype)
