"""Mixture-of-Experts: token-choice top-k router with capacity-based dispatch.

Expert weights are stacked (E, ...) and sharded over the `model` mesh axis
(expert parallelism). Dispatch is capacity-bounded per *row* (a row is one
sequence during training, or the whole batch during decode), built from a
cumulative-sum position assignment and scatter-add — no (T, E, C) dense
one-hot dispatch tensor is ever materialized.

Returns the combined output and the Switch-style load-balancing aux loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.common import Spec, dense_specs
from repro.sharding.rules import lc


def moe_specs(cfg: ArchConfig) -> Dict:
    assert cfg.moe is not None
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    gated = cfg.activation in ("geglu", "swiglu")
    specs = {
        "router": {"kernel": Spec((d, e), ("embed", "experts"), init="normal")},
        "up": {"kernel": Spec((e, d, ff), ("experts", "embed", "ff"), init="normal")},
        "down": {"kernel": Spec((e, ff, d), ("experts", "ff", "embed"), init="normal")},
    }
    if gated:
        specs["gate"] = {"kernel": Spec((e, d, ff), ("experts", "embed", "ff"),
                                        init="normal")}
    return specs


def _capacity(tokens_per_row: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(tokens_per_row * m.num_experts_per_tok / m.num_experts
            * m.capacity_factor)
    return max(c, m.num_experts_per_tok)


def route(params, x, cfg: ArchConfig):
    """x: (R, T, d) -> (gates (R,T,k), idx (R,T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum("rtd,de->rte", x.astype(jnp.float32),
                        params["router"]["kernel"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.num_experts_per_tok)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    f = jnp.mean(jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32),
                 axis=(0, 1, 2))
    p = jnp.mean(probs, axis=(0, 1))
    aux = m.num_experts * jnp.sum(f * p)
    return gates, idx, aux


def _dispatch_compute_combine(local_w, xr, gates, idx, cap: int,
                              cfg: ArchConfig, e_base, e_local: int):
    """Capacity dispatch -> expert FFN -> combine, for experts
    [e_base, e_base + e_local). ``local_w`` holds the shard-local expert
    weights {up, down[, gate]} each (E_local, ...). xr: (R, T, d)."""
    m = cfg.moe
    dtype = jnp.dtype(cfg.dtype)
    r, tok, d = xr.shape
    k = m.num_experts_per_tok

    flat_e = idx.reshape(r, tok * k)                       # global expert ids
    local_e = flat_e - e_base
    is_local = (local_e >= 0) & (local_e < e_local)
    local_e = jnp.where(is_local, local_e, e_local)        # overflow bucket
    onehot = jax.nn.one_hot(local_e, e_local + 1, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1)
    keep = is_local & (pos <= cap)
    slot = jnp.clip(pos - 1, 0, cap - 1)
    local_e = jnp.where(keep, local_e, e_local)            # masked -> bucket

    x_rep = jnp.repeat(xr, k, axis=1).astype(dtype)
    x_rep = x_rep * keep[..., None].astype(dtype)
    r_idx = jnp.arange(r)[:, None]
    dispatch = jnp.zeros((r, e_local + 1, cap, d), dtype)
    dispatch = dispatch.at[r_idx, local_e, slot].add(x_rep)
    dispatch = dispatch[:, :e_local]

    up = jnp.einsum("recd,edf->recf", dispatch,
                    local_w["up"].astype(dtype))
    if cfg.activation in ("geglu", "swiglu"):
        act = "gelu" if cfg.activation == "geglu" else "silu"
        h = common.activation(act)(
            jnp.einsum("recd,edf->recf", dispatch,
                       local_w["gate"].astype(dtype))) * up
    else:
        h = common.activation(cfg.activation)(up)
    out = jnp.einsum("recf,efd->recd", h, local_w["down"].astype(dtype))

    out = jnp.concatenate(
        [out, jnp.zeros((r, 1, cap, d), out.dtype)], axis=1)
    gathered = out[r_idx, local_e, slot]                   # (R, N, d)
    gathered = gathered * (gates.reshape(r, tok * k)[..., None].astype(dtype)
                           * keep[..., None].astype(dtype))
    return gathered.reshape(r, tok, k, d).sum(axis=2)


def _apply_moe_shard_map(params, x, cfg: ArchConfig, rules
                         ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE: experts sharded over the model axis,
    activations replicated across it; each shard dispatches + computes its
    local experts for all of its batch-shard's tokens, then one psum
    combines — per-layer communication equals a tensor-parallel FFN
    all-reduce instead of GSPMD's gathered-scatter (see EXPERIMENTS.md
    §Perf for the measured delta vs 'dense_einsum')."""
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    mesh = rules.mesh
    expert_ax = rules.table.get("experts")
    batch_ax = rules.table.get("batch")
    if isinstance(expert_ax, tuple):
        expert_ax = expert_ax[0] if expert_ax else None
    n_expert_shards = mesh.shape[expert_ax] if expert_ax else 1
    b, t, d = x.shape
    decode = t == 1

    def shard_fn(router_w, local_w, x):
        b_local = x.shape[0]
        xr = x.reshape(1, -1, d) if decode else x
        r, tok, _ = xr.shape
        gates, idx, aux = route({"router": {"kernel": router_w}}, xr, cfg)
        e_local = m.num_experts // n_expert_shards
        e_base = (jax.lax.axis_index(expert_ax) * e_local
                  if expert_ax else 0)
        cap = _capacity(tok, cfg) * (2 if decode else 1)
        y = _dispatch_compute_combine(local_w, xr, gates, idx, cap, cfg,
                                      e_base, e_local)
        if expert_ax:
            y = jax.lax.psum(y, expert_ax)
        if decode:
            y = y.reshape(b_local, 1, d)
        if batch_ax:
            aux = jax.lax.pmean(aux, batch_ax)
        return y, aux

    e_spec = P(expert_ax, None, None) if expert_ax else P()
    local_w = {"up": params["up"]["kernel"],
               "down": params["down"]["kernel"]}
    w_specs = {"up": e_spec, "down": e_spec}
    if "gate" in params:
        local_w["gate"] = params["gate"]["kernel"]
        w_specs["gate"] = e_spec
    from repro.sharding.compat import shard_map
    y, aux = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), w_specs, P(batch_ax, None, None)),
        out_specs=(P(batch_ax, None, None), P()),
        check_vma=False,
    )(params["router"]["kernel"], local_w, x)
    return lc(y, ("batch", "seq", "embed")), aux


def _shards(mesh, ax):
    if ax is None:
        return 1
    axes = (ax,) if isinstance(ax, str) else ax
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def apply_moe(params, x, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d). Returns (y (B,T,d), aux_loss)."""
    from repro.sharding.rules import get_rules
    rules = get_rules()
    if cfg.moe.dispatch_impl == "shard_map_a2a" and rules is not None:
        return _apply_moe_shard_map(params, x, cfg, rules)
    m = cfg.moe
    dtype = jnp.dtype(cfg.dtype)
    b, t, d = x.shape
    decode = t == 1
    if decode:
        # treat the whole batch as one dispatch row
        xr = x.reshape(1, b, d)
    else:
        xr = x
    r, tok, _ = xr.shape
    k = m.num_experts_per_tok
    cap = _capacity(tok, cfg) if not decode else _capacity(
        tok, cfg.replace(moe=m)) * 2  # decode rows are tiny; be generous

    gates, idx, aux = route(params, xr, cfg)

    # --- dispatch bookkeeping -------------------------------------------
    flat_e = idx.reshape(r, tok * k)                       # (R, N)
    onehot = jax.nn.one_hot(flat_e, m.num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) * onehot              # 1-based
    pos = pos.sum(-1)                                      # (R, N)
    keep = pos <= cap
    slot = jnp.clip(pos - 1, 0, cap - 1)

    x_rep = jnp.repeat(xr, k, axis=1).astype(dtype)        # (R, N, d)
    x_rep = x_rep * keep[..., None].astype(dtype)
    r_idx = jnp.arange(r)[:, None]
    dispatch = jnp.zeros((r, m.num_experts, cap, d), dtype)
    dispatch = dispatch.at[r_idx, flat_e, slot].add(x_rep)
    dispatch = lc(dispatch, ("batch", "experts", "expert_cap", "embed"))

    # --- expert FFN ------------------------------------------------------
    up = jnp.einsum("recd,edf->recf", dispatch,
                    params["up"]["kernel"].astype(dtype))
    if cfg.activation in ("geglu", "swiglu"):
        act = "gelu" if cfg.activation == "geglu" else "silu"
        g = jnp.einsum("recd,edf->recf", dispatch,
                       params["gate"]["kernel"].astype(dtype))
        h = common.activation(act)(g) * up
    else:
        h = common.activation(cfg.activation)(up)
    h = lc(h, ("batch", "experts", "expert_cap", "ff"))
    out = jnp.einsum("recf,efd->recd", h,
                     params["down"]["kernel"].astype(dtype))
    out = lc(out, ("batch", "experts", "expert_cap", "embed"))

    # --- combine ----------------------------------------------------------
    gathered = out[r_idx, flat_e, slot]                    # (R, N, d)
    gathered = gathered * (gates.reshape(r, tok * k)[..., None].astype(dtype)
                           * keep[..., None].astype(dtype))
    y = gathered.reshape(r, tok, k, d).sum(axis=2)
    if decode:
        y = y.reshape(b, t, d)
    y = lc(y, ("batch", "seq", "embed"))
    return y, aux.astype(jnp.float32)
