"""LSTM core used by the paper's own agent architectures (Fig. 3).

The IMPALA learner folds time into batch everywhere except the LSTM; the
LSTM itself runs under ``lax.scan`` over time, with the actor-provided
initial state (the paper sends the initial LSTM state with each
trajectory) and episode-boundary resets via the `done` flags.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Spec, dense, dense_specs


def lstm_specs(d_in: int, width: int) -> Dict:
    return {
        "wx": dense_specs((d_in,), (4 * width,), ("embed",), (None,), bias=True),
        "wh": dense_specs((width,), (4 * width,), (None,), (None,)),
    }


def lstm_step(params, carry, x):
    """carry = (h, c) each (B, W); x (B, d_in)."""
    h, c = carry
    gates = dense(params["wx"], x) + dense(params["wh"], h)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return (h_new, c_new), h_new


def lstm_apply(params, x, initial_state, done=None):
    """x: (B, T, d_in); initial_state = (h0, c0) each (B, W).

    done: optional (B, T) bool — resets state *before* consuming step t
    (episode boundary handling for trajectories that span episodes).
    Returns (outputs (B, T, W), final_state).
    """
    def body(carry, inp):
        if done is None:
            xt = inp
        else:
            xt, dt = inp
            mask = (1.0 - dt.astype(jnp.float32))[:, None]
            carry = (carry[0] * mask, carry[1] * mask)
        return lstm_step(params, carry, xt)

    xs = jnp.moveaxis(x, 1, 0)
    inputs = xs if done is None else (xs, jnp.moveaxis(done, 1, 0))
    final, ys = jax.lax.scan(body, initial_state, inputs)
    return jnp.moveaxis(ys, 0, 1), final


def lstm_zero_state(batch: int, width: int):
    z = jnp.zeros((batch, width), jnp.float32)
    return (z, z)
