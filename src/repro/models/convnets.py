"""The paper's own agent torsos (Figure 3).

Shallow: Conv 8x8/4 x16 -> Conv 4x4/2 x32 -> FC 256 (1.2M params w/ LSTM).
Deep: 3 sections of [conv3x3 + maxpool/2 + 2 residual blocks (2x conv3x3)]
with channels (16, 32, 32), then FC 256 (15 conv layers, 1.6M params).

Inputs are (B, H, W, C) uint8 pixels in [0, 255].
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Spec, dense, dense_specs


def _conv_spec(name: str, kh, kw, cin, cout) -> Dict[str, Spec]:
    return {"kernel": Spec((kh, kw, cin, cout), (None, None, None, None),
                           init="normal"),
            "bias": Spec((cout,), (None,), init="zeros")}


def _conv(params, x, stride: int, padding: str = "SAME"):
    y = jax.lax.conv_general_dilated(
        x, params["kernel"].astype(x.dtype),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + params["bias"].astype(x.dtype)


def _maxpool(x, window: int = 3, stride: int = 2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "SAME")


def _flat_dim(hw: Tuple[int, int, int], reductions: int, channels: int) -> int:
    h, w, _ = hw
    for _ in range(reductions):
        h = math.ceil(h / 2)
        w = math.ceil(w / 2)
    return h * w * channels


# ---------------------------------------------------------------------------
# Shallow


def shallow_specs(image_hw, d_out: int = 256) -> Dict:
    h, w, c = image_hw
    h2 = math.ceil(math.ceil((h - 4) / 4 + 1) / 2)  # valid-ish; use SAME: h/4 then /2
    del h2
    flat = _flat_dim(image_hw, 3, 32)  # strides 4 then 2 => /8 total
    return {
        "conv1": _conv_spec("conv1", 8, 8, c, 16),
        "conv2": _conv_spec("conv2", 4, 4, 16, 32),
        "fc": dense_specs((flat,), (d_out,), (None,), ("embed",), bias=True),
    }


def shallow_apply(params, img) -> jax.Array:
    x = img.astype(jnp.float32) / 255.0
    x = jax.nn.relu(_conv(params["conv1"], x, 4))
    x = jax.nn.relu(_conv(params["conv2"], x, 2))
    x = x.reshape(x.shape[0], -1)
    return jax.nn.relu(dense(params["fc"], x))


# ---------------------------------------------------------------------------
# Deep residual


_DEEP_CHANNELS = (16, 32, 32)


def deep_specs(image_hw, d_out: int = 256) -> Dict:
    c_in = image_hw[2]
    specs: Dict = {}
    for s, ch in enumerate(_DEEP_CHANNELS):
        sec: Dict = {"conv": _conv_spec(f"s{s}", 3, 3, c_in, ch)}
        for b in range(2):
            sec[f"res{b}a"] = _conv_spec(f"s{s}r{b}a", 3, 3, ch, ch)
            sec[f"res{b}b"] = _conv_spec(f"s{s}r{b}b", 3, 3, ch, ch)
        specs[f"section{s}"] = sec
        c_in = ch
    flat = _flat_dim(image_hw, len(_DEEP_CHANNELS), _DEEP_CHANNELS[-1])
    specs["fc"] = dense_specs((flat,), (d_out,), (None,), ("embed",), bias=True)
    return specs


def deep_apply(params, img) -> jax.Array:
    x = img.astype(jnp.float32) / 255.0
    for s in range(len(_DEEP_CHANNELS)):
        sec = params[f"section{s}"]
        x = _conv(sec["conv"], x, 1)
        x = _maxpool(x)
        for b in range(2):
            y = jax.nn.relu(x)
            y = _conv(sec[f"res{b}a"], y, 1)
            y = jax.nn.relu(y)
            y = _conv(sec[f"res{b}b"], y, 1)
            x = x + y
    x = jax.nn.relu(x)
    x = x.reshape(x.shape[0], -1)
    return jax.nn.relu(dense(params["fc"], x))
