"""Decoder stacks: block types, scan-over-layers, enc-dec and VLM wiring.

A *block* is a temporal mixer + (optionally) an FFN with pre-norms:
  attn       full causal self-attention + MLP
  local      sliding-window self-attention + MLP
  recurrent  RG-LRU + MLP (recurrentgemma)
  ssm        Mamba-2 SSD (no separate FFN; d_ff = 0)
  moe        full causal self-attention + MoE FFN
  cross      cross-attention (VLM image layers) + MLP
  enc_dec    self-attn + cross-attn + MLP (whisper decoder)
  enc        bidirectional self-attention + MLP (whisper encoder)

Layers are grouped into the minimal repeating pattern and scanned
(`lax.scan`) over stacked parameters so the HLO stays compact for the
512-device dry-run; `cfg.remat` wraps the scan body in jax.checkpoint.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.common import Spec, make_norm
from repro.sharding.rules import lc

PyTree = Any


# ---------------------------------------------------------------------------
# Layer plan


def layer_plan(cfg: ArchConfig) -> Tuple[List[str], List[str]]:
    """Returns (scanned_kinds, leftover_kinds): the repeating group pattern
    and the unrolled remainder."""
    fam = cfg.family
    if fam == "dense":
        kinds = ["local" if cfg.sliding_window else "attn"]
        return kinds, []
    if fam == "moe":
        return ["moe"], []
    if fam == "ssm":
        return ["ssm"], []
    if fam == "hybrid":
        pattern = ["recurrent" if p == "recurrent" else "local"
                   for p in cfg.rglru.pattern]
        n_groups = cfg.num_layers // len(pattern)
        leftover = cfg.num_layers - n_groups * len(pattern)
        return pattern, pattern[:leftover]
    if fam == "vlm":
        k = cfg.cross_attn_every
        group = ["attn"] * (k - 1) + ["cross"]
        assert cfg.num_layers % k == 0
        return group, []
    if fam == "audio":
        return ["enc_dec"], []
    raise ValueError(fam)


def num_groups(cfg: ArchConfig) -> int:
    group, leftover = layer_plan(cfg)
    return (cfg.num_layers - len(leftover)) // len(group)


# ---------------------------------------------------------------------------
# Single block


def block_specs(cfg: ArchConfig, kind: str) -> Dict:
    d = cfg.d_model
    norm_specs, _ = make_norm(cfg.norm, d)
    specs: Dict = {"norm1": norm_specs}
    if kind in ("attn", "local", "enc", "moe"):
        specs["attn"] = attn_lib.attention_specs(cfg)
        specs["norm2"] = norm_specs
        specs["ffn"] = (moe_lib.moe_specs(cfg) if kind == "moe"
                        else mlp_lib.mlp_specs(cfg))
    elif kind == "recurrent":
        specs["rglru"] = rglru_lib.rglru_specs(cfg)
        specs["norm2"] = norm_specs
        specs["ffn"] = mlp_lib.mlp_specs(cfg)
    elif kind == "ssm":
        specs["ssm"] = ssm_lib.ssm_specs(cfg)
    elif kind == "cross":
        specs["xattn"] = attn_lib.attention_specs(cfg)
        specs["norm2"] = norm_specs
        specs["ffn"] = mlp_lib.mlp_specs(cfg)
    elif kind == "enc_dec":
        specs["attn"] = attn_lib.attention_specs(cfg)
        specs["normx"] = norm_specs
        specs["xattn"] = attn_lib.attention_specs(cfg)
        specs["norm2"] = norm_specs
        specs["ffn"] = mlp_lib.mlp_specs(cfg)
    else:
        raise ValueError(kind)
    return specs


def apply_block(params, x, positions, cfg: ArchConfig, kind: str, *,
                mode: str, cache: Optional[PyTree],
                cross_ctx: Optional[jax.Array]):
    """Returns (x, new_cache, aux_loss)."""
    _, norm = make_norm(cfg.norm, cfg.d_model)
    aux = jnp.zeros((), jnp.float32)
    new_cache: PyTree = None

    def attn_cache():
        return None if cache is None else cache

    if kind in ("attn", "local", "enc", "moe"):
        h = norm(params["norm1"], x)
        window = cfg.sliding_window if kind == "local" else 0
        if kind == "local" and cfg.rglru is not None:
            window = cfg.rglru.attention_window
        y, kv = attn_lib.apply_attention(
            params["attn"], h, positions, cfg,
            causal=(kind != "enc"), window=window, mode=mode,
            cache=None if cache is None else cache.get("kv"),
            cache_index=None if cache is None else cache.get("index"))
        x = x + y
        h = norm(params["norm2"], x)
        if kind == "moe":
            y, aux = moe_lib.apply_moe(params["ffn"], h, cfg)
        else:
            y = mlp_lib.apply_mlp(params["ffn"], h, cfg)
        x = x + y
        if kv is not None:
            new_cache = {"kv": kv}
    elif kind == "recurrent":
        h = norm(params["norm1"], x)
        y, st = rglru_lib.apply_rglru(
            params["rglru"], h, cfg, mode=mode,
            state=None if cache is None else cache.get("rglru"))
        x = x + y
        h = norm(params["norm2"], x)
        x = x + mlp_lib.apply_mlp(params["ffn"], h, cfg)
        if st is not None:
            new_cache = {"rglru": st}
    elif kind == "ssm":
        h = norm(params["norm1"], x)
        y, st = ssm_lib.apply_ssm(
            params["ssm"], h, cfg, mode=mode,
            state=None if cache is None else cache.get("ssm"))
        x = x + y
        if st is not None:
            new_cache = {"ssm": st}
    elif kind == "cross":
        h = norm(params["norm1"], x)
        if mode == "decode" and cache is not None and "cross_kv" in cache:
            y = attn_lib.apply_cross_attention_cached(
                params["xattn"], h, cache["cross_kv"], cfg)
            new_cache = {"cross_kv": cache["cross_kv"]}
        else:
            assert cross_ctx is not None
            y, _ = attn_lib.apply_attention(
                params["xattn"], h, positions, cfg, kv_x=cross_ctx, mode=mode)
            if mode == "prefill":
                new_cache = {"cross_kv": attn_lib.precompute_cross_cache(
                    params["xattn"], cross_ctx, cfg)}
        x = x + y
        h = norm(params["norm2"], x)
        x = x + mlp_lib.apply_mlp(params["ffn"], h, cfg)
    elif kind == "enc_dec":
        h = norm(params["norm1"], x)
        y, kv = attn_lib.apply_attention(
            params["attn"], h, positions, cfg, causal=True, mode=mode,
            cache=None if cache is None else cache.get("kv"),
            cache_index=None if cache is None else cache.get("index"))
        x = x + y
        h = norm(params["normx"], x)
        if mode == "decode" and cache is not None and "cross_kv" in cache:
            y = attn_lib.apply_cross_attention_cached(
                params["xattn"], h, cache["cross_kv"], cfg)
        else:
            assert cross_ctx is not None
            y, _ = attn_lib.apply_attention(
                params["xattn"], h, positions, cfg, kv_x=cross_ctx, mode=mode)
        x = x + y
        h = norm(params["norm2"], x)
        x = x + mlp_lib.apply_mlp(params["ffn"], h, cfg)
        nc = {}
        if kv is not None:
            nc["kv"] = kv
        if mode == "prefill":
            nc["cross_kv"] = attn_lib.precompute_cross_cache(
                params["xattn"], cross_ctx, cfg)
        elif mode == "decode" and cache is not None and "cross_kv" in cache:
            nc["cross_kv"] = cache["cross_kv"]
        new_cache = nc or None
    else:
        raise ValueError(kind)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stacked scan over groups


def stack_specs(specs: PyTree, n: int) -> PyTree:
    def f(s: Spec) -> Spec:
        return Spec((n,) + s.shape, ("layers",) + s.logical,
                    init=s.init, dtype=s.dtype, scale=s.scale)
    return jax.tree.map(f, specs, is_leaf=lambda x: isinstance(x, Spec))


def group_specs(cfg: ArchConfig) -> Dict:
    group, leftover = layer_plan(cfg)
    n = num_groups(cfg)
    one_group = {f"l{i}": block_specs(cfg, k) for i, k in enumerate(group)}
    specs: Dict = {"scan": stack_specs(one_group, n)}
    for i, k in enumerate(leftover):
        specs[f"tail{i}"] = block_specs(cfg, k)
    return specs


def _cache_index_tree(cache):
    return cache


def apply_stack(params, x, positions, cfg: ArchConfig, *, mode: str,
                caches: Optional[PyTree] = None,
                cache_index: Optional[jax.Array] = None,
                cross_ctx: Optional[jax.Array] = None):
    """Run the full layer stack.

    caches: {'scan': stacked-per-group cache pytree (leading dim = n_groups),
             'tail<i>': per-layer cache} or None.
    Returns (x, new_caches (same structure) or None, total_aux).
    """
    group, leftover = layer_plan(cfg)
    total_aux = jnp.zeros((), jnp.float32)

    def group_body(carry, per_group):
        h, auxc = carry
        p, cache = per_group
        new_caches = {}
        for i, kind in enumerate(group):
            c = None if cache is None else cache.get(f"l{i}")
            if c is not None and cache_index is not None and "kv" in c:
                c = dict(c, index=cache_index)
            h, nc, aux = apply_block(p[f"l{i}"], h, positions, cfg, kind,
                                     mode=mode, cache=c, cross_ctx=cross_ctx)
            if nc is not None:
                nc.pop("index", None)
                new_caches[f"l{i}"] = nc
            auxc = auxc + aux
        return (h, auxc), (new_caches if new_caches else None)

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body)

    scan_caches = None if caches is None else caches.get("scan")
    if cfg.scan_layers:
        (x, total_aux), new_scan_caches = jax.lax.scan(
            body, (x, total_aux), (params["scan"], scan_caches))
    else:
        # unrolled: same stacked params/caches, python loop (dry-run mode —
        # XLA cost_analysis counts a while body once, unrolling keeps the
        # roofline FLOPs/bytes honest)
        n = jax.tree.leaves(params["scan"])[0].shape[0]
        collected = []
        carry = (x, total_aux)
        for gi in range(n):
            p_g = jax.tree.map(lambda a: a[gi], params["scan"])
            c_g = (None if scan_caches is None else
                   jax.tree.map(lambda a: a[gi], scan_caches))
            carry, nc = body(carry, (p_g, c_g))
            collected.append(nc)
        x, total_aux = carry
        if collected and collected[0] is not None:
            new_scan_caches = jax.tree.map(
                lambda *xs: jnp.stack(xs), *collected)
        else:
            new_scan_caches = None

    new_caches: Dict = {}
    if new_scan_caches is not None:
        new_caches["scan"] = new_scan_caches
    for i, kind in enumerate(leftover):
        c = None if caches is None else caches.get(f"tail{i}")
        if c is not None and cache_index is not None and "kv" in c:
            c = dict(c, index=cache_index)
        x, nc, aux = apply_block(params[f"tail{i}"], x, positions, cfg, kind,
                                 mode=mode, cache=c, cross_ctx=cross_ctx)
        if nc is not None:
            nc.pop("index", None)
            new_caches[f"tail{i}"] = nc
        total_aux = total_aux + aux
    return x, (new_caches if new_caches else None), total_aux


# ---------------------------------------------------------------------------
# Whisper-style encoder (bidirectional)


def encoder_specs(cfg: ArchConfig) -> Dict:
    enc_cfg = cfg  # same dims
    one = block_specs(enc_cfg, "enc")
    return {"scan": stack_specs(one, cfg.encoder_layers)}


def apply_encoder(params, embeds, cfg: ArchConfig):
    """embeds: (B, T_enc, d) stub frontend output."""
    b, t, _ = embeds.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    def body(h, p):
        h, _, _ = apply_block(p, h, positions, cfg, "enc",
                              mode="train", cache=None, cross_ctx=None)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, embeds, params["scan"])
    else:
        x = embeds
        n = jax.tree.leaves(params["scan"])[0].shape[0]
        for gi in range(n):
            x, _ = body(x, jax.tree.map(lambda a: a[gi], params["scan"]))
    return x
