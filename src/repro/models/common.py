"""Parameter-spec trees and common layers (from-scratch, no flax).

A model is described by a *spec tree*: a nested dict whose leaves are
``Spec(shape, logical, init, dtype)``. From the spec tree we derive
  * concrete initialized parameters       (``init_params``)
  * abstract ShapeDtypeStructs            (``abstract_params`` — dry-run,
    never allocates)
  * NamedShardings for pjit in_shardings  (``param_shardings``)

Apply functions are plain functions over the params dict. Activations are
annotated with logical sharding axes via ``repro.sharding.rules.lc``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import Rules, lc

PyTree = Any


# ---------------------------------------------------------------------------
# Specs


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed | uniform_scale
    dtype: str = "float32"
    scale: float = 1.0            # multiplier on the default init scale

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # contraction dims are all but the last by convention
    return int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]


def _init_leaf(spec: Spec, key: jax.Array) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape, dtype) * spec.scale).astype(dtype)
    if spec.init == "normal":
        std = spec.scale / math.sqrt(max(_fan_in(spec.shape), 1))
        return (jax.random.normal(key, spec.shape) * std).astype(dtype)
    if spec.init == "uniform_scale":
        lim = spec.scale * math.sqrt(3.0 / max(_fan_in(spec.shape), 1))
        return jax.random.uniform(key, spec.shape, dtype, -lim, lim)
    raise ValueError(f"unknown init {spec.init}")


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def init_params(specs: PyTree, key: jax.Array) -> PyTree:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_leaf(s, k) for s, k in zip(leaves, keys)])


def abstract_params(specs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs, is_leaf=_is_spec)


def param_shardings(specs: PyTree, rules: Rules) -> PyTree:
    return jax.tree.map(
        lambda s: rules.sharding(s.logical, s.shape), specs, is_leaf=_is_spec)


def param_count(specs: PyTree) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=_is_spec))


def cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(
        x.dtype, jnp.floating) else x, tree)


# ---------------------------------------------------------------------------
# Normalization


def rmsnorm_specs(d: int, name_axis: str = "embed") -> Dict[str, Spec]:
    return {"scale": Spec((d,), (name_axis,), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_specs(d: int, name_axis: str = "embed") -> Dict[str, Spec]:
    return {"scale": Spec((d,), (name_axis,), init="ones"),
            "bias": Spec((d,), (name_axis,), init="zeros")}


def layernorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def make_norm(kind: str, d: int):
    if kind == "rmsnorm":
        return rmsnorm_specs(d), rmsnorm
    if kind == "layernorm":
        return layernorm_specs(d), layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Dense (einsum) layers


def dense_specs(in_shape: Sequence[int], out_shape: Sequence[int],
                in_logical: Sequence[Optional[str]],
                out_logical: Sequence[Optional[str]],
                bias: bool = False, scale: float = 1.0) -> Dict[str, Spec]:
    shape = tuple(in_shape) + tuple(out_shape)
    logical = tuple(in_logical) + tuple(out_logical)
    specs = {"kernel": Spec(shape, logical, init="normal", scale=scale)}
    if bias:
        specs["bias"] = Spec(tuple(out_shape), tuple(out_logical), init="zeros")
    return specs


def dense(params, x, contract: int = 1, dtype=None):
    """Contract the trailing `contract` dims of x with leading dims of kernel."""
    k = params["kernel"]
    if dtype is not None:
        k = k.astype(dtype)
    n_out = k.ndim - contract
    dn = (tuple(range(x.ndim - contract, x.ndim)), tuple(range(contract)))
    y = jax.lax.dot_general(x, k, (dn, ((), ())))
    if "bias" in params:
        b = params["bias"]
        if dtype is not None:
            b = b.astype(dtype)
        y = y + b
    del n_out
    return y


# ---------------------------------------------------------------------------
# Embedding


def embedding_specs(vocab: int, d: int) -> Dict[str, Spec]:
    return {"table": Spec((vocab, d), ("vocab", "embed"), init="embed", scale=0.02)}


def embed(params, ids: jax.Array, dtype) -> jax.Array:
    out = jnp.take(params["table"].astype(dtype), ids, axis=0)
    return out


def unembed(params, x: jax.Array, dtype) -> jax.Array:
    return jnp.einsum("...d,vd->...v", x, params["table"].astype(dtype))


# ---------------------------------------------------------------------------
# RoPE


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations


def activation(name: str) -> Callable[[jax.Array], jax.Array]:
    return {
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
        "tanh": jnp.tanh,
    }[name]
