"""Feed-forward blocks: plain MLP, GeGLU (gemma), SwiGLU (llama-family)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common
from repro.models.common import Spec, dense, dense_specs
from repro.sharding.rules import lc


def mlp_specs(cfg: ArchConfig, d_ff: int = 0) -> Dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    gated = cfg.activation in ("geglu", "swiglu")
    specs = {
        "up": dense_specs((d,), (ff,), ("embed",), ("ff",)),
        "down": dense_specs((ff,), (d,), ("ff",), ("embed",)),
    }
    if gated:
        specs["gate"] = dense_specs((d,), (ff,), ("embed",), ("ff",))
    return specs


def apply_mlp(params, x, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    act = cfg.activation
    up = dense(params["up"], x, dtype=dtype)
    up = lc(up, ("batch", "seq", "ff"))
    if act == "geglu":
        h = common.activation("gelu")(dense(params["gate"], x, dtype=dtype)) * up
    elif act == "swiglu":
        h = common.activation("silu")(dense(params["gate"], x, dtype=dtype)) * up
    else:
        h = common.activation(act)(up)
    h = lc(h, ("batch", "seq", "ff"))
    y = dense(params["down"], h, dtype=dtype)
    return lc(y, ("batch", "seq", "embed"))
