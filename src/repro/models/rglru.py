"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a u_t + b_a)              recurrence gate
    i_t = sigmoid(W_x u_t + b_x)              input gate
    a_t = exp(c * r_t * log sigmoid(Lambda))  (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The diagonal linear recurrence is the sequential hot spot targeted by
``kernels/linear_scan.py``; the reference path uses a chunked scan.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import Spec, dense, dense_specs
from repro.models.ssm import _causal_conv
from repro.sharding.rules import lc

_C = 8.0


def _width(cfg: ArchConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def rglru_specs(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    w = _width(cfg)
    cw = cfg.rglru.conv_width
    return {
        # Griffin recurrent block: two input branches + output proj
        "in_gate": dense_specs((d,), (w,), ("embed",), ("lru",)),   # gelu branch
        "in_rec": dense_specs((d,), (w,), ("embed",), ("lru",)),    # recurrent branch
        "conv": {"kernel": Spec((cw, w), ("conv", "lru"), init="normal"),
                 "bias": Spec((w,), ("lru",), init="zeros")},
        "gate_a": dense_specs((w,), (w,), ("lru",), (None,), bias=True),
        "gate_x": dense_specs((w,), (w,), ("lru",), (None,), bias=True),
        "lam": {"w": Spec((w,), ("lru",), init="normal")},
        "out": dense_specs((w,), (d,), ("lru",), ("embed",)),
    }


def chunked_diag_scan(a, b, h0=None, chunk: int = 256):
    """h_t = a_t * h_{t-1} + b_t over axis 1. a, b: (B, T, W) float32.

    Sequential over chunks (bounded memory), associative within a chunk.
    Returns (h (B,T,W), h_final (B,W)).
    """
    bsz, t, w = a.shape
    pad = (-t) % chunk
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    nc = (t + pad) // chunk
    ac = jnp.moveaxis(a.reshape(bsz, nc, chunk, w), 1, 0)
    bc = jnp.moveaxis(b.reshape(bsz, nc, chunk, w), 1, 0)
    h_init = jnp.zeros((bsz, w), jnp.float32) if h0 is None else h0

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    def body(h, inp):
        ak, bk = inp
        aa, bb = jax.lax.associative_scan(combine, (ak, bk), axis=1)
        hk = aa * h[:, None, :] + bb
        return hk[:, -1], hk

    h_final, hs = jax.lax.scan(body, h_init, (ac, bc))
    h = jnp.moveaxis(hs, 0, 1).reshape(bsz, nc * chunk, w)[:, :t]
    return h, h_final


def rglru_core(params, u, h0=None, chunk: int = 256):
    """u: (B,T,W) -> (h (B,T,W) f32, h_final (B,W) f32)."""
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(params["gate_a"], u32))
    i = jax.nn.sigmoid(dense(params["gate_x"], u32))
    log_a = _C * r * jax.nn.log_sigmoid(params["lam"]["w"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u32)
    return chunked_diag_scan(a, gated, h0, chunk)


def rglru_core_step(params, u, h):
    """u: (B,W), h: (B,W) -> (y, h')."""
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(params["gate_a"], u32))
    i = jax.nn.sigmoid(dense(params["gate_x"], u32))
    log_a = _C * r * jax.nn.log_sigmoid(params["lam"]["w"].astype(jnp.float32))
    a = jnp.exp(log_a)
    h_new = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u32)
    return h_new, h_new


def apply_rglru(params, x, cfg: ArchConfig, *, mode: str = "train",
                state: Optional[Dict[str, jax.Array]] = None,
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Griffin recurrent block. x: (B,T,d_model).

    state = {'h': (B,W) f32, 'conv': (B, conv_width-1, W)}.
    """
    dtype = jnp.dtype(cfg.dtype)
    w = _width(cfg)
    bsz, t, _ = x.shape

    gate_branch = jax.nn.gelu(dense(params["in_gate"], x, dtype=dtype))
    rec = dense(params["in_rec"], x, dtype=dtype)
    rec = lc(rec, ("batch", "seq", "lru"))

    conv_state = state["conv"] if state is not None else None
    rec, new_conv = _causal_conv(rec, params["conv"]["kernel"],
                                 params["conv"]["bias"], conv_state)

    if mode == "decode":
        assert state is not None and t == 1
        h_new, y = rglru_core_step(params, rec[:, 0], state["h"])
        y = y[:, None]
        new_state = {"h": h_new, "conv": new_conv}
    else:
        h0 = state["h"] if state is not None else None
        y, h_final = rglru_core(params, rec)
        if mode == "prefill":
            cw = cfg.rglru.conv_width
            conv_in = dense(params["in_rec"], x, dtype=dtype)
            tail = conv_in[:, -(cw - 1):]
            if tail.shape[1] < cw - 1:
                tail = jnp.pad(tail, ((0, 0), (cw - 1 - tail.shape[1], 0), (0, 0)))
            new_state = {"h": h_final, "conv": tail}
        else:
            new_state = None

    y = y.astype(dtype) * gate_branch
    y = lc(y, ("batch", "seq", "lru"))
    out = dense(params["out"], y, dtype=dtype)
    return lc(out, ("batch", "seq", "embed")), new_state


def rglru_state_abstract(batch: int, cfg: ArchConfig, dtype):
    w = _width(cfg)
    cw = cfg.rglru.conv_width
    return {"h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, cw - 1, w), dtype)}
