"""Backbone assembly: embedding -> layer stack -> RL heads.

Every backbone maps observations to ``AgentOutput(policy_logits, values)``.
Three entry points mirror the IMPALA split:
  apply_train    full (B, T) trajectory  -> logits/values per step (learner)
  apply_prefill  full (B, T) context     -> logits at last step + cache (actor)
  apply_decode   one step + cache        -> logits/values + new cache (actor)

``family == 'impala_cnn'`` is the paper's own agent (conv torso folded over
time + LSTM core), consuming pixel observations.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import convnets, lstm as lstm_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as tfm
from repro.models.common import (Spec, dense, dense_specs, embed,
                                 embedding_specs, make_norm)
from repro.sharding.rules import lc

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AgentOutput:
    policy_logits: jax.Array  # (B, T, A) float32
    values: jax.Array         # (B, T)   float32
    aux_loss: jax.Array       # scalar
    cache: Optional[PyTree] = None


# ---------------------------------------------------------------------------
# Specs


def head_specs(cfg: ArchConfig, num_actions: int) -> Dict:
    d = cfg.d_model if cfg.family != "impala_cnn" else 256
    norm_specs, _ = make_norm(cfg.norm, cfg.d_model)
    specs = {
        "policy": dense_specs((d,), (num_actions,), ("embed",), ("actions",),
                              bias=True, scale=0.01),
        "value": dense_specs((d,), (1,), ("embed",), (None,), bias=True,
                             scale=0.01),
    }
    if cfg.family != "impala_cnn":
        specs["final_norm"] = norm_specs
    return specs


def backbone_specs(cfg: ArchConfig, num_actions: int) -> Dict:
    if cfg.family == "impala_cnn":
        torso = (convnets.shallow_specs(cfg.image_hw)
                 if cfg.impala_net == "shallow"
                 else convnets.deep_specs(cfg.image_hw))
        specs: Dict = {"torso": torso}
        if cfg.use_lstm:
            specs["lstm"] = lstm_lib.lstm_specs(256 + num_actions + 1,
                                                cfg.lstm_width)
            specs["post_lstm"] = dense_specs(
                (cfg.lstm_width,), (256,), (None,), ("embed",), bias=True)
        specs.update(head_specs(cfg, num_actions))
        return specs

    specs = {
        "embed": embedding_specs(cfg.vocab_size, cfg.d_model),
        "stack": tfm.group_specs(cfg),
    }
    if cfg.encoder_layers:
        specs["encoder"] = tfm.encoder_specs(cfg)
    specs.update(head_specs(cfg, num_actions))
    return specs


# ---------------------------------------------------------------------------
# Heads


def _apply_heads(params, x, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    if "final_norm" in params:
        _, norm = make_norm(cfg.norm, cfg.d_model)
        x = norm(params["final_norm"], x)
    logits = dense(params["policy"], x).astype(jnp.float32)
    values = dense(params["value"], x).astype(jnp.float32)[..., 0]
    return logits, values


# ---------------------------------------------------------------------------
# Cross-modal context (stub frontends)


def _cross_ctx(params, batch: Dict, cfg: ArchConfig, dtype):
    if cfg.family == "audio":
        enc_in = batch["enc_embed"].astype(dtype)
        return tfm.apply_encoder(params["encoder"], enc_in, cfg)
    if cfg.family == "vlm":
        return batch["image_embed"].astype(dtype)
    return None


# ---------------------------------------------------------------------------
# Sequence-model paths


def apply_train(params, batch: Dict, cfg: ArchConfig,
                num_actions: int) -> AgentOutput:
    if cfg.family == "impala_cnn":
        return _impala_net_apply(params, batch, cfg, num_actions, mode="train")
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = embed(params["embed"], tokens, dtype)
    x = lc(x, ("batch", "seq", "embed"))
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    cross = _cross_ctx(params, batch, cfg, dtype)
    x, _, aux = tfm.apply_stack(params["stack"], x, positions, cfg,
                                mode="train", cross_ctx=cross)
    logits, values = _apply_heads(params, x, cfg)
    return AgentOutput(logits, values, aux)


def apply_prefill(params, batch: Dict, cfg: ArchConfig,
                  num_actions: int) -> AgentOutput:
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = embed(params["embed"], tokens, dtype)
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    cross = _cross_ctx(params, batch, cfg, dtype)
    x, caches, aux = tfm.apply_stack(params["stack"], x, positions, cfg,
                                     mode="prefill", cross_ctx=cross)
    logits, values = _apply_heads(params, x[:, -1:], cfg)
    return AgentOutput(logits, values, aux, cache=caches)


def apply_decode(params, token: jax.Array, cache: PyTree,
                 cache_index: jax.Array, cfg: ArchConfig,
                 num_actions: int,
                 batch: Optional[Dict] = None) -> AgentOutput:
    """token: (B, 1) int32; cache_index: scalar int32 (absolute position)."""
    dtype = jnp.dtype(cfg.dtype)
    b = token.shape[0]
    x = embed(params["embed"], token, dtype)
    positions = jnp.broadcast_to(cache_index[None, None], (b, 1)).astype(jnp.int32)
    # cross context comes from cache (prefill stored projected enc k/v);
    # for dry-run decode without prefill, allow fresh ctx via batch
    cross = None
    if batch is not None and cfg.family in ("audio", "vlm"):
        cross = _cross_ctx(params, batch, cfg, dtype)
    x, new_caches, aux = tfm.apply_stack(
        params["stack"], x, positions, cfg, mode="decode",
        caches=cache, cache_index=cache_index, cross_ctx=cross)
    logits, values = _apply_heads(params, x, cfg)
    return AgentOutput(logits, values, aux, cache=new_caches)


# ---------------------------------------------------------------------------
# Cache construction


def _block_cache_abstract(kind: str, batch: int, length: int,
                          cfg: ArchConfig, dtype):
    dh = cfg.resolved_head_dim
    if kind in ("attn", "moe"):
        spec = attn_lib.CacheSpec(length, cfg.num_kv_heads, dh)
        return {"kv": attn_lib.cache_abstract(batch, spec, dtype)}
    if kind == "local":
        window = (cfg.rglru.attention_window if cfg.rglru is not None
                  else cfg.sliding_window)
        spec = attn_lib.CacheSpec(min(window, length), cfg.num_kv_heads, dh)
        return {"kv": attn_lib.cache_abstract(batch, spec, dtype)}
    if kind == "recurrent":
        return {"rglru": rglru_lib.rglru_state_abstract(batch, cfg, dtype)}
    if kind == "ssm":
        return {"ssm": ssm_lib.ssm_state_abstract(batch, cfg, dtype)}
    if kind == "cross":
        shape = (batch, cfg.encoder_seq_len, cfg.num_kv_heads, dh)
        return {"cross_kv": {"k": jax.ShapeDtypeStruct(shape, dtype),
                             "v": jax.ShapeDtypeStruct(shape, dtype)}}
    if kind == "enc_dec":
        spec = attn_lib.CacheSpec(length, cfg.num_kv_heads, dh)
        shape = (batch, cfg.encoder_seq_len, cfg.num_kv_heads, dh)
        return {"kv": attn_lib.cache_abstract(batch, spec, dtype),
                "cross_kv": {"k": jax.ShapeDtypeStruct(shape, dtype),
                             "v": jax.ShapeDtypeStruct(shape, dtype)}}
    raise ValueError(kind)


def _stack_abstract(tree: PyTree, n: int) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_abstract(batch: int, length: int, cfg: ArchConfig) -> PyTree:
    """Abstract (ShapeDtypeStruct) decode cache for the full stack."""
    dtype = jnp.dtype(cfg.dtype)
    group, leftover = tfm.layer_plan(cfg)
    n = tfm.num_groups(cfg)
    one = {f"l{i}": _block_cache_abstract(k, batch, length, cfg, dtype)
           for i, k in enumerate(group)}
    out: Dict = {"scan": _stack_abstract(one, n)}
    for i, k in enumerate(leftover):
        out[f"tail{i}"] = _block_cache_abstract(k, batch, length, cfg, dtype)
    return out


def cache_init(batch: int, length: int, cfg: ArchConfig) -> PyTree:
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        cache_abstract(batch, length, cfg),
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _block_cache_axes(kind: str, cfg: ArchConfig) -> PyTree:
    kv = {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
          "v": ("batch", "kv_seq", "kv_heads", "head_dim")}
    if kind in ("attn", "moe", "local"):
        return {"kv": dict(kv)}
    if kind == "recurrent":
        return {"rglru": {"h": ("batch", "lru"),
                          "conv": ("batch", None, "lru")}}
    if kind == "ssm":
        return {"ssm": {"ssm": ("batch", "ssm_heads", None, None),
                        "conv": ("batch", None, "ff")}}
    if kind == "cross":
        return {"cross_kv": dict(kv)}
    if kind == "enc_dec":
        return {"kv": dict(kv), "cross_kv": dict(kv)}
    raise ValueError(kind)


def cache_logical_axes(cfg: ArchConfig) -> PyTree:
    """Logical axes mirroring ``cache_abstract``'s structure."""
    group, leftover = tfm.layer_plan(cfg)
    one = {f"l{i}": _block_cache_axes(k, cfg) for i, k in enumerate(group)}
    stacked = jax.tree.map(lambda ax: ("layers",) + tuple(ax), one,
                           is_leaf=lambda x: isinstance(x, tuple))
    out: Dict = {"scan": stacked}
    for i, k in enumerate(leftover):
        out[f"tail{i}"] = _block_cache_axes(k, cfg)
    return out


# ---------------------------------------------------------------------------
# The paper's conv(+LSTM) agent


def _impala_net_apply(params, batch: Dict, cfg: ArchConfig, num_actions: int,
                      *, mode: str) -> AgentOutput:
    """batch: image (B,T,H,W,C) uint8, last_action (B,T) int32,
    last_reward (B,T) f32, done (B,T) bool, lstm_state ((B,W),(B,W))."""
    img = batch["image"]
    b, t = img.shape[:2]
    flat = img.reshape((b * t,) + img.shape[2:])
    feats = (convnets.shallow_apply(params["torso"], flat)
             if cfg.impala_net == "shallow"
             else convnets.deep_apply(params["torso"], flat))
    feats = feats.reshape(b, t, -1)
    aux = jnp.zeros((), jnp.float32)
    state = None
    if cfg.use_lstm:
        last_a = jax.nn.one_hot(batch["last_action"], num_actions,
                                dtype=feats.dtype)
        last_r = batch["last_reward"][..., None].astype(feats.dtype)
        core_in = jnp.concatenate([feats, last_a, last_r], axis=-1)
        lstm_state = batch.get("lstm_state")
        if lstm_state is None:
            lstm_state = lstm_lib.lstm_zero_state(b, cfg.lstm_width)
        ys, state = lstm_lib.lstm_apply(params["lstm"], core_in, lstm_state,
                                        done=batch.get("done"))
        feats = jax.nn.relu(dense(params["post_lstm"], ys))
    logits, values = _apply_heads(params, feats, cfg)
    return AgentOutput(logits, values, aux, cache=state)
