"""Pure-JAX environments (the data substrate — everything vmappable/jittable).

Five tasks echoing the paper's DMLab suite at CPU scale:
  catch        reactive control (ball + paddle)
  rooms        navigation + collection ('rooms_collect_good_objects'-like)
  tmaze        memory (cue at start, decision at the end — needs the LSTM)
  chase        pursuit of a scripted bot, variable-length episodes
               (throughput Table 1 'task 2' analogue)
  bandit       contextual bandit (pure credit assignment)

API: each env is an ``Env`` with ``reset(key) -> state`` and
``step(state, action, key) -> (state, TimeStep)``; episodes auto-reset and
signal boundaries through ``done``. Observations come in two forms: a
token id (LLM backbones) and a rendered uint8 image (the paper's conv
agents).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class TimeStep(NamedTuple):
    obs_token: jax.Array    # () int32
    obs_image: jax.Array    # (H, W, 3) uint8
    reward: jax.Array       # () f32
    done: jax.Array         # () bool  (episode ended at this transition)


@dataclasses.dataclass(frozen=True)
class Env:
    name: str
    num_actions: int
    vocab_size: int
    image_hw: Tuple[int, int, int]
    reset: Callable[[jax.Array], PyTree]
    step: Callable[[PyTree, jax.Array, jax.Array], Tuple[PyTree, TimeStep]]
    observe: Callable[[PyTree], TimeStep]


def _blank_image(hw):
    return jnp.zeros(hw, jnp.uint8)


def _paint(img, r, c, channel, value=255):
    return img.at[r, c, channel].set(value)


# ---------------------------------------------------------------------------
# catch


def make_catch(rows: int = 10, cols: int = 5) -> Env:
    hw = (rows, cols, 3)

    class S(NamedTuple):
        ball_r: jax.Array
        ball_c: jax.Array
        paddle: jax.Array
        t: jax.Array

    def _obs(s: S, reward=0.0, done=False) -> TimeStep:
        token = (s.ball_r * cols + s.ball_c) * cols + s.paddle
        img = _blank_image(hw)
        img = _paint(img, s.ball_r, s.ball_c, 0)
        img = _paint(img, rows - 1, s.paddle, 1)
        return TimeStep(token.astype(jnp.int32), img,
                        jnp.float32(reward), jnp.asarray(done))

    def reset(key):
        return S(jnp.int32(0), jax.random.randint(key, (), 0, cols),
                 jnp.int32(cols // 2), jnp.int32(0))

    def step(s: S, action, key):
        paddle = jnp.clip(s.paddle + action - 1, 0, cols - 1)
        ball_r = s.ball_r + 1
        done = ball_r >= rows - 1
        reward = jnp.where(done,
                           jnp.where(paddle == s.ball_c, 1.0, -1.0), 0.0)
        nxt = S(ball_r, s.ball_c, paddle, s.t + 1)
        fresh = reset(key)
        nxt = jax.tree.map(lambda a, b: jnp.where(done, a, b), fresh, nxt)
        ts = _obs(nxt, reward, done)
        return nxt, ts

    return Env("catch", 3, rows * cols * cols, hw, reset, step,
               lambda s: _obs(s))


# ---------------------------------------------------------------------------
# rooms (gridworld collection)


def make_rooms(n: int = 7, num_objects: int = 4, horizon: int = 80) -> Env:
    hw = (n, n, 3)

    class S(NamedTuple):
        pos: jax.Array          # (2,) int32
        objects: jax.Array      # (num_objects, 2) int32
        alive: jax.Array        # (num_objects,) bool
        t: jax.Array

    def _obs(s: S, reward=0.0, done=False) -> TimeStep:
        ncol = jnp.sum(~s.alive)
        token = (s.pos[0] * n + s.pos[1]) + n * n * ncol
        img = _blank_image(hw)
        img = img.at[s.pos[0], s.pos[1], 1].set(255)
        img = img.at[s.objects[:, 0], s.objects[:, 1], 0].set(
            jnp.where(s.alive, 255, 0).astype(jnp.uint8))
        return TimeStep(token.astype(jnp.int32), img,
                        jnp.float32(reward), jnp.asarray(done))

    def reset(key):
        k1, k2 = jax.random.split(key)
        pos = jax.random.randint(k1, (2,), 0, n)
        objects = jax.random.randint(k2, (num_objects, 2), 0, n)
        return S(pos, objects, jnp.ones((num_objects,), bool), jnp.int32(0))

    moves = jnp.array([[-1, 0], [1, 0], [0, -1], [0, 1], [0, 0]])

    def step(s: S, action, key):
        pos = jnp.clip(s.pos + moves[action], 0, n - 1)
        hit = s.alive & jnp.all(s.objects == pos[None], axis=1)
        reward = jnp.sum(hit).astype(jnp.float32)
        alive = s.alive & ~hit
        t = s.t + 1
        done = (t >= horizon) | ~jnp.any(alive)
        nxt = S(pos, s.objects, alive, t)
        fresh = reset(key)
        nxt = jax.tree.map(lambda a, b: jnp.where(done, a, b), fresh, nxt)
        return nxt, _obs(nxt, reward, done)

    return Env("rooms", 5, n * n * (num_objects + 1), hw, reset, step,
               lambda s: _obs(s))


# ---------------------------------------------------------------------------
# tmaze (memory)


def make_tmaze(length: int = 10) -> Env:
    hw = (3, length + 1, 3)

    class S(NamedTuple):
        pos: jax.Array
        cue: jax.Array   # 0/1
        t: jax.Array

    def _obs(s: S, reward=0.0, done=False) -> TimeStep:
        show_cue = s.pos == 0
        token = s.pos * 3 + jnp.where(show_cue, s.cue + 1, 0)
        img = _blank_image(hw)
        img = img.at[1, s.pos, 1].set(255)
        img = img.at[0, 0, 2].set(
            jnp.where(show_cue, (s.cue + 1) * 100, 0).astype(jnp.uint8))
        return TimeStep(token.astype(jnp.int32), img,
                        jnp.float32(reward), jnp.asarray(done))

    def reset(key):
        return S(jnp.int32(0), jax.random.randint(key, (), 0, 2), jnp.int32(0))

    def step(s: S, action, key):
        at_end = s.pos >= length - 1
        # actions: 0 forward, 1 up (choose), 2 down (choose)
        choosing = at_end & (action > 0)
        correct = (action - 1) == s.cue
        reward = jnp.where(choosing, jnp.where(correct, 1.0, -1.0), 0.0)
        pos = jnp.clip(s.pos + (action == 0), 0, length - 1)
        t = s.t + 1
        done = choosing | (t >= 3 * length)
        nxt = S(pos, s.cue, t)
        fresh = reset(key)
        nxt = jax.tree.map(lambda a, b: jnp.where(done, a, b), fresh, nxt)
        return nxt, _obs(nxt, reward, done)

    return Env("tmaze", 3, (length + 1) * 3, hw, reset, step,
               lambda s: _obs(s))


# ---------------------------------------------------------------------------
# chase (variable-length pursuit; scripted bot)


def make_chase(n: int = 9, horizon: int = 120) -> Env:
    hw = (n, n, 3)

    class S(NamedTuple):
        agent: jax.Array
        bot: jax.Array
        t: jax.Array
        caught: jax.Array

    def _obs(s: S, reward=0.0, done=False) -> TimeStep:
        token = (s.agent[0] * n + s.agent[1]) * n * n + (s.bot[0] * n + s.bot[1])
        img = _blank_image(hw)
        img = img.at[s.agent[0], s.agent[1], 1].set(255)
        img = img.at[s.bot[0], s.bot[1], 0].set(255)
        return TimeStep(token.astype(jnp.int32), img,
                        jnp.float32(reward), jnp.asarray(done))

    def reset(key):
        k1, k2 = jax.random.split(key)
        return S(jax.random.randint(k1, (2,), 0, n),
                 jax.random.randint(k2, (2,), 0, n),
                 jnp.int32(0), jnp.int32(0))

    moves = jnp.array([[-1, 0], [1, 0], [0, -1], [0, 1], [0, 0]])

    def step(s: S, action, key):
        agent = jnp.clip(s.agent + moves[action], 0, n - 1)
        # bot runs away along the axis of largest distance gain
        delta = jnp.sign(s.bot - agent)
        delta = jnp.where(delta == 0,
                          jax.random.randint(key, (2,), -1, 2), delta)
        bot = jnp.clip(s.bot + delta, 0, n - 1)
        tagged = jnp.all(agent == bot)
        reward = jnp.where(tagged, 1.0, -0.01)
        caught = s.caught + tagged
        t = s.t + 1
        # variable-length episodes: ends on 3 tags or horizon
        done = (caught >= 3) | (t >= horizon)
        nxt = S(agent, bot, t, caught)
        fresh = reset(jax.random.fold_in(key, 1))
        nxt = jax.tree.map(lambda a, b: jnp.where(done, a, b), fresh, nxt)
        return nxt, _obs(nxt, reward, done)

    return Env("chase", 5, n * n * n * n, hw, reset, step, lambda s: _obs(s))


# ---------------------------------------------------------------------------
# bandit (contextual)


def make_bandit(num_contexts: int = 16, num_actions: int = 4) -> Env:
    hw = (4, 4, 3)

    class S(NamedTuple):
        ctx: jax.Array

    def _obs(s: S, reward=0.0, done=False) -> TimeStep:
        img = _blank_image(hw)
        img = img.at[s.ctx // 4, s.ctx % 4, 2].set(255)
        return TimeStep(s.ctx.astype(jnp.int32), img,
                        jnp.float32(reward), jnp.asarray(done))

    def reset(key):
        return S(jax.random.randint(key, (), 0, num_contexts))

    def step(s: S, action, key):
        reward = jnp.where(action == (s.ctx % num_actions), 1.0, 0.0)
        nxt = reset(key)
        return nxt, _obs(nxt, reward, True)

    return Env("bandit", num_actions, num_contexts, hw, reset, step,
               lambda s: _obs(s))


# ---------------------------------------------------------------------------
# registry


ENV_MAKERS = {
    "catch": make_catch,
    "rooms": make_rooms,
    "tmaze": make_tmaze,
    "chase": make_chase,
    "bandit": make_bandit,
}


def make_env(name: str, **kw) -> Env:
    return ENV_MAKERS[name](**kw)


def make_suite(names=("catch", "rooms", "tmaze", "chase", "bandit")):
    """A multi-task suite with a shared (max) action/vocab space."""
    envs = [make_env(n) for n in names]
    return envs
