"""Multi-task suite utilities (paper §5.3): wrap heterogeneous envs to a
shared observation frame + action space so one agent (one set of weights)
can be trained across tasks with per-task actor allocation."""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.data.envs import Env, TimeStep


def common_frame(envs: Sequence[Env]) -> Tuple[Tuple[int, int, int], int]:
    hw = (max(e.image_hw[0] for e in envs),
          max(e.image_hw[1] for e in envs), 3)
    num_actions = max(e.num_actions for e in envs)
    return hw, num_actions


def padded_env(env: Env, max_hw, num_actions: int) -> Env:
    """Pad images to a common frame; clamp out-of-range actions."""

    def fix_ts(ts: TimeStep) -> TimeStep:
        img = jnp.zeros(max_hw, jnp.uint8)
        img = jax.lax.dynamic_update_slice(img, ts.obs_image, (0, 0, 0))
        return TimeStep(ts.obs_token, img, ts.reward, ts.done)

    def step(s, a, key):
        a = jnp.minimum(a, env.num_actions - 1)
        s, ts = env.step(s, a, key)
        return s, fix_ts(ts)

    return dataclasses.replace(
        env, num_actions=num_actions, image_hw=max_hw, step=step,
        observe=lambda s: fix_ts(env.observe(s)))
