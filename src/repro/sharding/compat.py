"""shard_map across jax versions.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax.shard_map`` (and its replication-check keyword was renamed
``check_rep`` -> ``check_vma``) across jax releases. Every caller in this
repo goes through :func:`shard_map` below so the codebase runs on both
API generations.
"""
from __future__ import annotations

import jax

try:
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:  # older jax: experimental API, check_rep keyword
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-agnostic ``jax.shard_map``.

    ``check_vma`` (new name; maps onto ``check_rep`` on older jax) is only
    forwarded when explicitly given, so each jax version keeps its own
    default.
    """
    kwargs = {}
    if check_vma is not None:
        kwargs[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)
