"""Named sharding-rule profiles, per (arch, shape) overridable.

Two families live here, deliberately separated:

IMPALA profiles (used by this repo's training paths)
    'baseline' — the only profile the IMPALA conv-LSTM net trains
    with. It resolves to ``DEFAULT_RULES``: the batch axis maps to
    ``("pod", "data")`` and every param dim falls through the
    divisibility rule. The SPMD learner (``--learner-mode spmd``)
    builds its 1-D ``('data',)`` mesh and uses exactly these rules —
    batch sharded on the leading trajectory axis when the row count
    divides the mesh (``Rules.spec``'s fallback replicates otherwise),
    params replicated because a ~129k-param conv-LSTM has nothing
    worth sharding. ``launch/dryrun.py`` compiles the same profile on
    the big production meshes.

Legacy LLM dryrun profiles (kept compiling, not used by IMPALA)
    Everything below 'baseline' targets the transformer/MoE/SSM dryrun
    shapes from the production-mesh exercise (``launch/dryrun.py``'s
    assigned-architecture sweep); none of their logical axes (heads,
    kv_seq, experts, vocab, ...) appear in the IMPALA param tree, so
    selecting them for IMPALA is a no-op beyond the batch rule. They
    are retained because the sharding tests pin their specs
    (``tests/test_sharding.py`` exercises baseline/seq_data/tp2d/
    fsdp_pure) and the dryrun tooling still selects them by name; each
    one's comment records the hypothesis it was hillclimbing.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.configs.base import ArchConfig, InputShape


def get_profile(name: str, arch: ArchConfig,
                shape: InputShape) -> Optional[Dict]:
    # ---- IMPALA profile ------------------------------------------------
    if name == "baseline":
        return None  # DEFAULT_RULES — the profile IMPALA trains with

    # ---- legacy LLM dryrun profiles (see module docstring) -------------
    if name == "seq_data":
        # shard sequence (not batch) over data — context parallelism for
        # small-batch long-context shapes (long_500k B=1)
        return {"batch": None, "seq": ("pod", "data"),
                "kv_seq": ("pod", "data")}
    if name == "kv_data":
        # decode: shard the KV cache sequence dim over the data axis
        # (flash-decode style distributed attention)
        return {"kv_seq": "data"}
    if name == "expert_data":
        # MoE: put experts on (data, model) jointly — more expert shards,
        # less tensor parallelism
        return {"experts": ("data", "model"), "ff": None}
    if name == "fsdp":
        # ZeRO-ish: shard params over data too (embed dim over data)
        return {"embed": "data"}
    if name == "tp2d":
        # §Perf: for the (data, model_a=4, model_b=4) mesh — heads shard
        # 4-way on model_a (20 % 4 == 0), ffn/vocab/experts use the full
        # 16-way (model_a, model_b) product.
        return {"batch": ("pod", "data"),
                "heads": "model_a", "kv_heads": "model_a",
                "head_dim": None,
                "ff": ("model_a", "model_b"),
                "vocab": ("model_a", "model_b"),
                "experts": ("model_a", "model_b"),
                "lru": ("model_a", "model_b"),
                "ssm_heads": ("model_a", "model_b")}
    if name == "fsdp_moe":
        # §Perf: FSDP x expert-parallel hybrid for MoE. fsdp_pure leaves
        # experts unsharded on the expert dim, so every device gathers the
        # full expert bank (olmoe: ~27 GB/step). Keep experts on the model
        # axis (shard_map EP) and shard the remaining param dims over
        # data (ZeRO); batch stays (pod, data).
        return {"batch": ("pod", "data"),
                "experts": "model",
                "embed": "data",
                "heads": None, "kv_heads": None, "head_dim": None,
                "ff": None, "vocab": None}
    if name == "fsdp_cp":
        # §Perf: multi-pod FSDP. batch 256 does not divide 512 devices, so
        # fsdp_pure's divisibility fallback silently REPLICATES the whole
        # batch across the mesh (measured: 295 s collective). Instead:
        # batch 256-way over (data, model), sequence 2-way over pod
        # (context parallelism), params sharded on embed.
        return {"batch": ("data", "model"),
                "seq": "pod", "kv_seq": "pod",
                "embed": ("data", "model"),
                "heads": None, "kv_heads": None, "head_dim": None,
                "ff": None, "vocab": None, "experts": None,
                "lru": None, "ssm_heads": None}
    if name == "kv_head_dim":
        # §Perf: GQA archs with kv_heads < model axis (mistral/granite/vlm
        # kv=8 on 16-way TP) replicate k/v projections and the KV cache.
        # head_dim stays mapped AFTER heads/kv_heads in each tensor, so
        # adding head_dim->model only bites where the head count failed
        # divisibility: q stays head-sharded, k/v shard head_dim.
        return {"head_dim": "model"}
    if name == "head_dim_tp":
        # §Perf: archs whose head COUNT is not divisible by the model axis
        # (qwen 20H, whisper 12H, recurrentgemma 10H) replicate all
        # attention under baseline rules. head_dim (128/256) IS divisible:
        # shard it instead; score einsums contract over head_dim -> psum.
        return {"heads": None, "kv_heads": None, "head_dim": "model"}
    if name == "fsdp_pure":
        # §Perf: swap tensor parallelism for fully-sharded data parallel.
        # batch over all axes (256/512-way); every weight sharded on its
        # embed dim; GSPMD all-gathers weights per layer (bf16) instead of
        # all-reducing activations per layer. Hypothesis: for train_4k on
        # >=10B dense, collective bytes drop ~3x and params/opt/grads
        # shard 256-way.
        return {"batch": ("pod", "data", "model"),
                "embed": ("data", "model"),
                "heads": None, "kv_heads": None, "head_dim": None,
                "ff": None, "vocab": None, "experts": None,
                "lru": None, "ssm_heads": None}
    raise KeyError(name)
