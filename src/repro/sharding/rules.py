"""Logical-axis sharding rules (t5x/MaxText style) with divisibility fallback.

Tensors throughout the model code are annotated with *logical* axis names
(``('batch', 'seq', 'embed')``). A ``Rules`` object maps logical names to
mesh axes and resolves them into ``PartitionSpec``s, replicating any
dimension whose size is not divisible by the mesh axis product (this is
what lets e.g. recurrentgemma's 10 heads lower on a 16-way model axis).

When no mesh is active (unit tests on CPU) all annotations are no-ops.
"""
from __future__ import annotations

import contextlib
import logging
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

logger = logging.getLogger(__name__)

MeshAxes = Union[None, str, Tuple[str, ...]]

# Baseline logical->mesh rules for a ('pod', 'data', 'model') mesh.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "ssm_state": None,
    "ssm_heads": "model",
    "lru": "model",
    "actions": None,
    "layers": None,
    "conv": None,
    "kv_seq": None,
    "stack": None,
}


class Rules:
    """Resolver from logical axis tuples to PartitionSpecs on a mesh."""

    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, MeshAxes]] = None):
        self.mesh = mesh
        table = dict(DEFAULT_RULES)
        if rules:
            table.update(rules)
        # Drop mesh axes that don't exist on this mesh (e.g. 'pod' on 2D mesh)
        clean: Dict[str, MeshAxes] = {}
        for k, v in table.items():
            if v is None:
                clean[k] = None
            else:
                axes = (v,) if isinstance(v, str) else tuple(v)
                axes = tuple(a for a in axes if a in mesh.axis_names)
                clean[k] = axes if axes else None
        self.table = clean

    def _axis_size(self, mesh_axes: MeshAxes) -> int:
        if mesh_axes is None:
            return 1
        axes = (mesh_axes,) if isinstance(mesh_axes, str) else mesh_axes
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """Resolve logical axes (+ optional shape for divisibility) to a spec."""
        parts = []
        used: set = set()
        for i, name in enumerate(logical):
            if name is None:
                parts.append(None)
                continue
            mesh_axes = self.table.get(name)
            if mesh_axes is None:
                parts.append(None)
                continue
            axes = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
            # an axis may appear only once in a spec
            axes = tuple(a for a in axes if a not in used)
            if not axes:
                parts.append(None)
                continue
            if shape is not None:
                size = self._axis_size(axes)
                if shape[i] % size != 0:
                    logger.debug(
                        "replicating logical axis %r (dim %d of size %d not "
                        "divisible by mesh %s=%d)", name, i, shape[i], axes, size)
                    parts.append(None)
                    continue
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        return P(*parts)

    def sharding(self, logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


# --------------------------------------------------------------------------
# Thread-local active rules so model code can annotate without plumbing.

_state = threading.local()


def get_rules() -> Optional[Rules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def logical_constraint(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """Apply with_sharding_constraint from logical axes; no-op without rules."""
    rules = get_rules()
    if rules is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, rules.sharding(logical, x.shape))


# Short alias used pervasively in model code.
lc = logical_constraint
