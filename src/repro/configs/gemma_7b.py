"""gemma-7b — dense decoder with GeGLU and head_dim=256.

[arXiv:2403.08295] 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000, GeGLU, head_dim=256 (the 2b sibling uses MQA).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24_576,
    vocab_size=256_000,
    head_dim=256,
    activation="geglu",
    source="arXiv:2403.08295",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, d_model=128, num_heads=2,
                          num_kv_heads=2, head_dim=64, d_ff=256,
                          vocab_size=512, remat=False)
