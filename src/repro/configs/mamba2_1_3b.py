"""mamba2-1.3b — attention-free SSM with state-space duality (SSD).

[arXiv:2405.21060] 48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128,
head_dim=64, expand=2 (d_inner=4096, 64 ssd heads).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    use_rope=False,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256,
                  conv_width=4),
    source="arXiv:2405.21060",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, chunk_size=16,
                      conv_width=4),
        remat=False)
