"""The paper's shallow agent (Fig. 3 left): 2 conv layers + LSTM, 1.2M params."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="impala-shallow",
    family="impala_cnn",
    num_layers=2,
    d_model=256,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=0,
    impala_net="shallow",
    image_hw=(72, 96, 3),
    use_lstm=True,
    lstm_width=256,
    remat=False,
    source="arXiv:1802.01561 Fig.3 (left)",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(image_hw=(24, 24, 3), lstm_width=64)
