"""recurrentgemma-2b — hybrid RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427] 26L d_model=2560 10H (GQA kv=1 / MQA) d_ff=7680
vocab=256000, head_dim=256, GeGLU, local attention window 2048.
"""
from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    activation="geglu",
    norm="rmsnorm",
    rglru=RGLRUConfig(lru_width=2560, conv_width=4,
                      pattern=("recurrent", "recurrent", "attention"),
                      attention_window=2048),
    source="arXiv:2402.19427",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        num_layers=3, d_model=128, num_heads=2, num_kv_heads=1, head_dim=64,
        d_ff=256, vocab_size=512,
        rglru=RGLRUConfig(lru_width=128, conv_width=4,
                          pattern=("recurrent", "recurrent", "attention"),
                          attention_window=32),
        remat=False)
