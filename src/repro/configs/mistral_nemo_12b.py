"""mistral-nemo-12b — dense decoder, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407] 40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072, head_dim=128, SwiGLU, rope theta 1e6.

``swa_variant()`` is the sliding-window variant (window 4096) used so the
``long_500k`` decode shape lowers sub-quadratically; the faithful CONFIG
stays full-attention (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=131_072,
    head_dim=128,
    activation="swiglu",
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)


def swa_variant() -> ArchConfig:
    return CONFIG.replace(name="mistral-nemo-12b-swa", sliding_window=4096)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=2, head_dim=32, d_ff=256,
                          vocab_size=512, remat=False)
