"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as an ``ArchConfig``; the RL /
IMPALA side is an ``ImpalaConfig``; distribution is a ``MeshConfig``.
Configs are plain frozen dataclasses so they hash and can be closed over
by jitted step functions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01
    # 'dense_einsum' (GSPMD auto) or 'shard_map_a2a' (explicit all_to_all)
    dispatch_impl: str = "dense_einsum"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""
    state_dim: int = 128          # N
    head_dim: int = 64            # P
    num_heads: int = 0            # derived: d_inner // head_dim if 0
    expand: int = 2
    chunk_size: int = 256
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block configuration."""
    lru_width: int = 0            # defaults to d_model if 0
    conv_width: int = 4
    # layer pattern: 'rr a' repeated -> 2 recurrent : 1 local attention
    pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    attention_window: int = 2048


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm | impala_cnn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // num_heads
    # activation: 'gelu' | 'silu' | 'geglu' | 'swiglu'
    activation: str = "swiglu"
    norm: str = "rmsnorm"         # 'rmsnorm' | 'layernorm'
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    sliding_window: int = 0       # 0 = full attention
    tie_embeddings: bool = False
    # encoder-decoder (whisper): encoder layer count; 0 = decoder-only
    encoder_layers: int = 0
    encoder_seq_len: int = 0      # stub frontend output length (frames/patches)
    # VLM: insert a cross-attention layer every k layers (0 = none)
    cross_attn_every: int = 0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # IMPALA conv nets (paper Fig. 3)
    impala_net: str = ""          # '' | 'shallow' | 'deep'
    image_hw: Tuple[int, int, int] = (72, 96, 3)
    use_lstm: bool = False
    lstm_width: int = 256
    # scan-over-layers group size (layers per scanned superblock)
    scan_group: int = 1
    # lax.scan over stacked layer groups (compact HLO, fast compile) vs
    # python-unrolled layers (XLA cost_analysis counts a while body once —
    # the dry-run unrolls so roofline FLOPs/bytes are honest)
    scan_layers: bool = True
    remat: bool = True
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # citation for the source model/paper
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# RL / IMPALA


@dataclasses.dataclass(frozen=True)
class ImpalaConfig:
    num_actions: int = 18                # Atari full action set by default
    unroll_length: int = 100             # n (paper Table D.3)
    discount: float = 0.99
    baseline_cost: float = 0.5
    entropy_cost: float = 0.00025
    rho_bar: float = 1.0                 # \bar{rho}
    c_bar: float = 1.0                   # \bar{c}
    lambda_: float = 1.0                 # Remark 2 extension
    correction: str = "vtrace"           # vtrace | onestep_is | eps | none
    # Appendix E.3: q_s = r + gamma*v_{s+1} ('vtrace', default/better) vs
    # q_s = r + gamma*V(x_{s+1}) ('baseline_v', no rollout information)
    pg_q_estimate: str = "vtrace"
    eps_correction: float = 1e-6
    reward_clip: str = "abs_one"         # abs_one | soft_asymmetric | none
    # replay (paper 5.2.2)
    replay_capacity: int = 10_000
    replay_fraction: float = 0.0         # 0.5 in the replay experiments
    replay_reuse: int = 2                # K: max total consumptions/traj
    replay_priority: str = "pertd"       # pertd | uniform (Ape-X prop.)
    replay_target_period: int = 16       # updates between target syncs
    # learner batch (trajectories per update)
    batch_size: int = 32
    # simulated policy lag (actor params k updates behind learner)
    policy_lag: int = 1
    learning_rate: float = 6e-4
    lr_anneal_steps: int = 0             # 0 = constant
    rmsprop_decay: float = 0.99
    rmsprop_momentum: float = 0.0
    rmsprop_eps: float = 0.1
    grad_clip_norm: float = 40.0
    seed: int = 0


# ---------------------------------------------------------------------------
# Mesh / distribution


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    data_axis: int = 16
    model_axis: int = 16
    pod_axis: int = 2

    @property
    def shape(self):
        if self.multi_pod:
            return (self.pod_axis, self.data_axis, self.model_axis)
        return (self.data_axis, self.model_axis)

    @property
    def axis_names(self):
        if self.multi_pod:
            return ("pod", "data", "model")
        return ("data", "model")


# ---------------------------------------------------------------------------
# Input shapes (assigned)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
