"""llama-3.2-vision-11b — VLM: cross-attention image layers every 5th layer.

[hf:meta-llama/Llama-3.2-11B-Vision] 40L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256. The ViT vision encoder + projector is a STUB:
input_specs provides precomputed patch embeddings (B, 1600, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    activation="swiglu",
    rope_theta=500_000.0,
    cross_attn_every=5,
    encoder_seq_len=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=5, d_model=128, num_heads=4,
                          num_kv_heads=2, d_ff=256, vocab_size=512,
                          encoder_seq_len=16, remat=False)
