"""olmoe-1b-7b — MoE, 64 experts top-8.

[arXiv:2409.02060] 16L d_model=2048 16H (GQA kv=16) d_ff=1024 (per expert)
vocab=50304, MoE 64e top-8, SwiGLU.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50_304,
    activation="swiglu",
    moe=MoEConfig(num_experts=64, num_experts_per_tok=8),
    source="arXiv:2409.02060",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=64,
        vocab_size=512, moe=MoEConfig(num_experts=4, num_experts_per_tok=2),
        remat=False)
