"""The paper's deep agent (Fig. 3 right): 15-conv resnet + LSTM, 1.6M params."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="impala-deep",
    family="impala_cnn",
    num_layers=15,
    d_model=256,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=0,
    impala_net="deep",
    image_hw=(72, 96, 3),
    use_lstm=True,
    lstm_width=256,
    remat=False,
    source="arXiv:1802.01561 Fig.3 (right)",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(image_hw=(24, 24, 3), lstm_width=64)
