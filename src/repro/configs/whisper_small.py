"""whisper-small — encoder-decoder audio backbone.

[arXiv:2212.04356] 12L (decoder; +12L encoder) d_model=768 12H (kv=12)
d_ff=3072 vocab=51865. The mel-spectrogram + conv frontend is a STUB:
input_specs provides precomputed frame embeddings (B, 1500, d_model).
LayerNorm + GELU + QKV bias as in the source; positions via RoPE (the
original uses learned/sinusoidal embeddings — TPU-repro adaptation noted
in DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    activation="gelu",
    norm="layernorm",
    qkv_bias=True,
    encoder_layers=12,
    encoder_seq_len=1500,
    source="arXiv:2212.04356",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, encoder_layers=2, d_model=128,
                          num_heads=4, num_kv_heads=4, d_ff=256,
                          vocab_size=512, encoder_seq_len=24, remat=False)
