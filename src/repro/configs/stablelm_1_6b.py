"""stablelm-1.6b — dense decoder.

[hf:stabilityai/stablelm-2-1_6b] 24L d_model=2048 32H (GQA kv=32)
d_ff=5632 vocab=100352, SwiGLU, LayerNorm, partial-RoPE source (full RoPE
here), qkv bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100_352,
    activation="swiglu",
    norm="layernorm",
    qkv_bias=True,
    source="hf:stabilityai/stablelm-2-1_6b",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=4, d_ff=256, vocab_size=512,
                          remat=False)
