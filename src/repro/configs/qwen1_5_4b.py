"""qwen1.5-4b — dense decoder with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family] 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936, SwiGLU, QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151_936,
    activation="swiglu",
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(num_layers=2, d_model=128, num_heads=4,
                          num_kv_heads=4, d_ff=256, vocab_size=512,
                          remat=False)
