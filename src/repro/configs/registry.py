"""Architecture config registry: ``get_config(name)`` / ``list_configs()``.

Each assigned architecture lives in its own module defining ``CONFIG`` (the
exact assigned shape) and ``smoke_config()`` (a reduced variant for CPU
smoke tests: <=2 layers, d_model <= 512, <= 4 experts).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

_ARCH_MODULES = [
    "recurrentgemma_2b",
    "granite_moe_1b_a400m",
    "whisper_small",
    "mamba2_1_3b",
    "stablelm_1_6b",
    "gemma_7b",
    "qwen1_5_4b",
    "llama_3_2_vision_11b",
    "mistral_nemo_12b",
    "olmoe_1b_7b",
    "impala_shallow",
    "impala_deep",
]

_ALIASES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "whisper-small": "whisper_small",
    "mamba2-1.3b": "mamba2_1_3b",
    "stablelm-1.6b": "stablelm_1_6b",
    "gemma-7b": "gemma_7b",
    "qwen1.5-4b": "qwen1_5_4b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "impala-shallow": "impala_shallow",
    "impala-deep": "impala_deep",
}

ASSIGNED = _ARCH_MODULES[:10]


def _module(name: str):
    mod = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod not in _ARCH_MODULES:
        raise KeyError(f"unknown architecture {name!r}; known: {list_configs()}")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return _module(name).smoke_config()


def list_configs() -> List[str]:
    return list(_ARCH_MODULES)


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in _ARCH_MODULES}
