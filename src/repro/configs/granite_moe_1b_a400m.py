"""granite-moe-1b-a400m — MoE, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base] 24L d_model=1024 16H (GQA kv=8)
d_ff=512 (per expert) vocab=49155, MoE 32e top-8, SwiGLU.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    activation="swiglu",
    moe=MoEConfig(num_experts=32, num_experts_per_tok=8),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=64,
        vocab_size=512, moe=MoEConfig(num_experts=4, num_experts_per_tok=2),
        remat=False)
