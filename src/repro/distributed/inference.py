"""Dynamic-batching inference service (paper §3.1's batched actor
inference, service-shaped).

Instead of every actor paying a full policy forward for its own env
batch, actors in ``actor_mode='inference'`` become thin host-side env
steppers: each submits its per-step observation batch to one
``InferenceService`` that lives next to the learner, owns a single
jitted batched forward on the learner's device, and replies with
actions, behaviour log-probs, the next recurrent state, and the
parameter version it acted with. The service collects requests into
**power-of-two-bucketed** batches (at most log2 jit variants) and
flushes on whichever comes first:

  full      a max-size bucket of requests is pending;
  ready     every connected client has a request in (nobody else can
            submit — waiting longer is pure stall);
  timeout   the oldest pending request has waited ``flush_timeout_s``
            (stragglers don't gate the fleet).

Two client frontends share the service core:

  thread    ``service.connect()`` — requests are live array pytrees on a
            lock-protected deque, replies delivered through an Event.
  process   ``service.process_frontend(ctx)`` — requests travel as
            serde-encoded frames over a bounded multiprocessing wire,
            replies go back serde-encoded over a per-client pipe (the
            same byte boundary the trajectory pipeline already uses).

The service is deliberately limited to the paper's conv-LSTM agent
(``impala_cnn``): its per-step state is the explicit (h, c) pair the
client carries, so the service itself stays stateless and any flush can
mix any clients. Token backbones decode against a growing per-client
cache and keep their per-actor unrolls.

Telemetry: per-flush batch-size histogram, full/ready/timeout flush
counts, and request queue-wait quantiles — the knobs this service adds
(bucket size, flush timeout) are all observable from
``telemetry_snapshot()['inference']``.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import serde
from repro.distributed.paramstore import ParameterStore
from repro.models import backbone as bb

PyTree = Any

_STOP_FRAME = b""          # reply-pipe sentinel: service shut down


class InferenceReply(NamedTuple):
    """One client's slice of a flushed batch."""
    action: Any                # (B,) int32
    logprob: Any               # (B,) f32 — behaviour log pi(a|x)
    lstm_state: Tuple[Any, Any]  # ((B, W), (B, W)) next recurrent state
    param_version: int


class _Pending(NamedTuple):
    data: PyTree               # request pytree (np or jax leaves)
    reply_fn: Callable[[Optional[InferenceReply]], None]
    submitted_at: float


class _Waiter:
    """Handle for an async in-process submission."""
    __slots__ = ("event", "slot")

    def __init__(self):
        self.event = threading.Event()
        self.slot: List[Optional[InferenceReply]] = [None]

    def deliver(self, r: Optional[InferenceReply]) -> None:
        self.slot[0] = r
        self.event.set()


def _wait_bucket(wait_s: float) -> int:
    """Power-of-two microsecond bucket for a queue wait: bucket ``k``
    covers ``[2^(k-1), 2^k)`` µs (k=0 is the sub-µs bucket). Integer
    keys so the registry's ``IntHistogram`` holds it and ``/metrics``
    renders one sample per bucket."""
    return max(0, int(wait_s * 1e6)).bit_length()


def _hist_quantile_ms(counts: Dict[int, int], q: float) -> float:
    """The q-quantile's bucket *upper bound* in ms, from a
    ``_wait_bucket`` histogram. Resolution is a factor of two — honest
    about what a bucketed histogram knows, and mergeable across
    learners, which the late point-sample deque this replaced was
    not."""
    total = sum(counts.values())
    if not total:
        return 0.0
    rank = q * total
    acc = 0
    for k in sorted(counts):
        acc += counts[k]
        if acc >= rank:
            return (1 << k) / 1e3
    return (1 << max(counts)) / 1e3


def _pow2_floor(n: int) -> int:
    b = 1
    while b * 2 <= n:
        b *= 2
    return b


def _pow2_ceil(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class InferenceService:
    """One jitted batched per-step policy forward, shared by all actors.

    Request pytree (leaves batched over the client's envs)::

        {"obs_image": (B,H,W,C) u8, "last_action": (B,) i32,
         "last_reward": (B,) f32, "done": (B,) bool,
         "lstm_h": (B,W) f32, "lstm_c": (B,W) f32}

    Params come from the ``ParameterStore`` (pulled once per flush), so
    the behaviour policy advances with the learner and every reply is
    stamped with the version that produced it — the client stamps its
    trajectory with the version of the unroll's *first* step, keeping
    measured policy lag conservative.
    """

    def __init__(self, env, arch_cfg, icfg, store: ParameterStore, *,
                 num_clients: int, flush_timeout_s: float = 0.02,
                 max_batch_requests: Optional[int] = None, seed: int = 0,
                 rng_key=None, registry=None):
        """``rng_key`` (a jax PRNG key) overrides the seed-derived
        sampling stream — a learner group passes each member's
        ``fold_in(key(seed), learner_id)`` key so no two learners'
        services ever share an action-sampling stream; single-learner
        runs keep the plain ``seed`` path byte-for-byte."""
        if arch_cfg.family != "impala_cnn":
            raise ValueError(
                "InferenceService batches the per-step conv-LSTM policy; "
                f"family {arch_cfg.family!r} decodes against a per-client "
                "cache — use actor_mode='unroll'")
        if num_clients < 1:
            raise ValueError("num_clients must be >= 1")
        self._arch = arch_cfg
        self._num_actions = env.num_actions
        self._store = store
        self.flush_timeout_s = flush_timeout_s
        self.max_batch_requests = _pow2_floor(
            max_batch_requests or num_clients)
        base_key = jax.random.key(seed) if rng_key is None else rng_key
        self._key = jax.random.fold_in(base_key, 0x1f5)
        self._flush_seq = 0
        self._flush_fns: Dict[int, Callable] = {}   # bucket -> jitted fn
        self._warmed = False
        self._warm_lock = threading.Lock()

        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._pending: collections.deque = collections.deque()
        self._clients = 0           # connected clients (both frontends)
        self._paused = 0            # clients blocked outside the service
                                    # (e.g. on trajectory backpressure)
        self._stop = threading.Event()
        self._frontends: List[ProcessFrontend] = []
        self.errors: List[BaseException] = []

        # telemetry (service-thread writes under self._lock, snapshot()
        # reads). The hot-path request/frame totals live in a metrics
        # registry when one is passed so a live /metrics pull and the
        # end-of-run snapshot read the same storage.
        if registry is None:
            from repro.obs.metrics import Registry
            registry = Registry()
        self.registry = registry
        self.batch_hist = registry.int_histogram(
            "inference.batch_hist").counts
        # queue waits live in a registry histogram (power-of-two µs
        # buckets), not a bounded deque of samples: the percentiles in
        # snapshot() derive from ALL waits since start, and /metrics
        # exposes the full distribution as bucket-labelled samples
        self.wait_hist = registry.int_histogram(
            "inference.queue_wait_hist").counts
        self._c_requests = registry.counter("inference.requests")
        self._c_frames = registry.counter("inference.frames")
        self.flush_full = 0
        self.flush_ready = 0
        self.flush_timeouts = 0
        self.padded_requests = 0
        self._last_version = -1

        self._thread = threading.Thread(target=self._loop,
                                        name="inference-service",
                                        daemon=True)
        self._started = False
        self._loop_needed = False   # only process frontends need the
        # background flusher: thread clients leader-execute full buckets
        # and their wait() deadline covers straggler flushes, so in a
        # thread-only run the loop would just burn ~hundreds of spurious
        # GIL wake-ups per second on every submit notify

    # counter views (the registry instruments are the storage)

    @property
    def requests(self) -> int:
        return self._c_requests.value

    @property
    def frames(self) -> int:
        return self._c_frames.value

    # ------------------------------------------------------------------
    # the jitted flush: concat K requests -> one forward -> sample

    def _build_flush(self, k: int) -> Callable:
        arch, num_actions = self._arch, self._num_actions
        base_key = self._key

        def flush(params, seq, reqs):
            # per-flush RNG stream derived *inside* the jit: a host-side
            # split/fold would cost one more device dispatch per flush
            key = jax.random.fold_in(base_key, seq)
            batch = (reqs[0] if k == 1 else
                     jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                  *reqs))
            n = batch["last_action"].shape[0]
            model_batch = {
                "image": batch["obs_image"][:, None],
                "last_action": batch["last_action"][:, None],
                "last_reward": batch["last_reward"][:, None],
                "done": batch["done"][:, None],
                "lstm_state": (batch["lstm_h"], batch["lstm_c"]),
            }
            out = bb.apply_train(params, model_batch, arch, num_actions)
            logits = out.policy_logits[:, 0]
            action = jax.random.categorical(key, logits,
                                            axis=-1).astype(jnp.int32)
            logp = jax.nn.log_softmax(logits)[jnp.arange(n), action]
            h, c = out.cache
            return action, logp, h, c

        return jax.jit(flush)

    def _warm_buckets(self, sample: PyTree) -> None:
        """Compile every pow2 bucket variant up front (first request
        only): a straggler-sized bucket first appearing mid-run would
        otherwise drop a ~100ms+ XLA compile into the acting critical
        path — startup is the place to pay for all of them."""
        if self._warmed:
            return
        with self._warm_lock:
            if self._warmed:
                return
            params, _ = self._store.pull()
            b = 1
            while b <= self.max_batch_requests:
                with self._lock:
                    fn = self._flush_fns.get(b)
                    if fn is None:
                        fn = self._flush_fns[b] = self._build_flush(b)
                jax.block_until_ready(fn(params, np.int64(0),
                                         (sample,) * b))
                b *= 2
            self._warmed = True

    # ------------------------------------------------------------------
    # service loop

    def _loop(self) -> None:
        try:
            while not self._stop.is_set():
                with self._cond:
                    batch, reason = self._take_locked()
                    if batch is None:
                        remaining = 0.05
                        if self._pending:
                            oldest = self._pending[0].submitted_at
                            remaining = max(0.0, self.flush_timeout_s -
                                            (time.monotonic() - oldest))
                        self._cond.wait(min(0.05, remaining)
                                        if self._pending else 0.05)
                        continue
                self._run_flush(batch, reason)
        except BaseException as e:     # surface in the learner thread
            self.errors.append(e)
            self.stop()

    def _take_locked(self) -> Tuple[Optional[List[_Pending]], str]:
        """Decide (under the lock) whether to flush now; pops the batch."""
        n = len(self._pending)
        if n == 0:
            return None, ""
        active = self._clients - self._paused
        if n >= self.max_batch_requests:
            k, reason = self.max_batch_requests, "full"
        elif self._clients and n >= max(1, active):
            # every client that *can* submit has a request in (paused
            # ones are blocked elsewhere, e.g. on trajectory
            # backpressure): waiting out the timeout cannot grow the
            # batch. Take everything up to the bucket — the flush pads
            # partial batches, it never splits a phase-coherent batch
            # into pow2 shards.
            k, reason = min(n, self.max_batch_requests), "ready"
        elif (time.monotonic() - self._pending[0].submitted_at
                >= self.flush_timeout_s):
            k, reason = min(n, self.max_batch_requests), "timeout"
        else:
            return None, ""
        return [self._pending.popleft() for _ in range(k)], reason

    def _run_flush(self, batch: List[_Pending], reason: str) -> None:
        # may run concurrently: on the service thread (timeout/frontend
        # flushes) and on leader client threads (full-bucket flushes) —
        # only the RNG advance and the jit cache need the lock, the
        # flush execution itself is free-threaded
        k = len(batch)
        # partial batches pad up to the power-of-two bucket by repeating
        # the last request (its duplicate replies are discarded): jit
        # variants stay log2-bounded and a phase-coherent partial batch
        # (e.g. 3 of 4 actors, the 4th mid-assembly) flushes whole
        # instead of splitting into pow2 shards
        kb = min(_pow2_ceil(k), self.max_batch_requests)
        self._warm_buckets(batch[0].data)
        with self._lock:
            fn = self._flush_fns[kb]
            self._flush_seq += 1
            seq = self._flush_seq
        params, version = self._store.pull()
        now = time.monotonic()
        reqs = [p.data for p in batch] + [batch[-1].data] * (kb - k)
        # materialize ONCE: the flush must complete before any reply is
        # usable, and numpy row slices are free views — handing out lazy
        # device slices instead makes every client pay its own forced
        # execution (~1ms each, measured) on its critical path
        action, logp, h, c = (np.asarray(x) for x in
                              fn(params, np.int64(seq), tuple(reqs)))

        with self._lock:        # snapshot() reads these concurrently
            self.batch_hist[k] += 1
            if reason == "full":
                self.flush_full += 1
            elif reason == "ready":
                self.flush_ready += 1
            else:
                self.flush_timeouts += 1
            self._c_requests.inc(k)
            self.padded_requests += kb - k
            self._last_version = version
            for p in batch:
                self._c_frames.inc(p.data["last_action"].shape[0])
                self.wait_hist[_wait_bucket(now - p.submitted_at)] += 1
        off = 0
        for p in batch:
            b = p.data["last_action"].shape[0]
            reply = InferenceReply(action[off:off + b], logp[off:off + b],
                                   (h[off:off + b], c[off:off + b]),
                                   version)
            off += b
            try:
                p.reply_fn(reply)
            except Exception as e:      # a dead pipe must not kill a flush
                self.errors.append(e)

    # ------------------------------------------------------------------
    # submission + thread frontend

    def submit(self, data: PyTree,
               reply_fn: Callable[[Optional[InferenceReply]], None],
               submitted_at: Optional[float] = None) -> bool:
        """Queue one request for the background flusher; False iff the
        service is shut down (the caller gets no reply and should
        exit). This is the process frontend's path — in-process clients
        use ``submit_and_wait``/``submit_async``, whose callers also
        flush."""
        if self._stop.is_set():
            return False
        with self._cond:
            if self._stop.is_set():
                return False
            self._pending.append(_Pending(
                data, reply_fn, submitted_at or time.monotonic()))
            self._cond.notify()
        return True

    def submit_async(self, data: PyTree) -> Optional[_Waiter]:
        """Async submit for in-process clients: queue the request and
        return a waiter (None if shut down). The notify wakes the
        service thread, which flushes as soon as a bucket completes —
        the submitter is free to go do other work (the dual-stream
        actors step their other env half-batch here, hiding the flush
        latency entirely)."""
        w = _Waiter()
        with self._cond:
            if self._stop.is_set():
                return None
            self._pending.append(_Pending(data, w.deliver,
                                          time.monotonic()))
            self._cond.notify()
        return w

    def wait(self, w: _Waiter) -> Optional[InferenceReply]:
        """Block until the waiter's flush lands. A waiter whose wait
        crosses the flush deadline turns **leader** and runs the partial
        flush itself, so stragglers cannot stall behind a busy service
        thread. Returns None on shutdown."""
        while True:
            if w.event.wait(timeout=self.flush_timeout_s):
                return w.slot[0]
            if self._stop.is_set():
                return None
            with self._cond:
                batch, reason = self._take_locked()
            if batch is not None:
                self._run_flush(batch, reason)

    def submit_and_wait(self, data: PyTree) -> Optional[InferenceReply]:
        """Blocking submit, with **leader-executed flushes**: if this
        request completes a bucket (or makes every connected client
        pending), the submitting thread runs the flush itself instead of
        handing off to the service thread — on a busy host the two extra
        thread wake-ups per flush (wake the service, then wake the
        clients) are pure latency on the acting critical path. Returns
        None on shutdown."""
        with self._cond:
            if self._stop.is_set():
                return None
            w = _Waiter()
            self._pending.append(_Pending(data, w.deliver,
                                          time.monotonic()))
            self._cond.notify()
            batch, reason = self._take_locked()
        while batch is not None:
            self._run_flush(batch, reason)
            # the popped batch is the *oldest* pending; with more
            # requesters than the bucket holds, ours may not be in it
            if w.event.is_set():
                return w.slot[0]
            with self._cond:
                batch, reason = self._take_locked()
        return self.wait(w)

    def drive_flushes(self) -> None:
        """Flush everything pending, now, on the calling thread — the
        hot path of the single-threaded inference *driver* (thread-mode
        acting): the driver submits every logical actor's request and
        immediately executes the flush(es) itself, so a full acting
        cycle involves zero cross-thread wake-ups. Bypasses the
        full/ready/timeout rules (the driver knows nobody else is about
        to submit); frontend requests that happen to be pending ride
        along in the same flushes."""
        while True:
            with self._cond:
                n = len(self._pending)
                if n == 0:
                    return
                k = min(n, self.max_batch_requests)
                batch = [self._pending.popleft() for _ in range(k)]
            self._run_flush(
                batch, "full" if k >= self.max_batch_requests else "ready")

    def connect(self) -> "InferenceClient":
        with self._lock:
            self._clients += 1
        return InferenceClient(self)

    def _disconnect(self) -> None:
        with self._cond:
            self._clients = max(0, self._clients - 1)
            self._cond.notify()     # remaining pending may now be "ready"

    def _pause(self) -> None:
        """A client signalling it is blocked outside the service (its
        transport put is backpressured): stop counting it towards the
        ready rule so the others' batches flush without waiting for it —
        otherwise one learner-throttled actor stalls the whole fleet on
        flush timeouts and breaks the bucket phase."""
        with self._cond:
            self._paused += 1
            self._cond.notify()

    def _resume(self) -> None:
        with self._cond:
            self._paused = max(0, self._paused - 1)

    def attach_frontend(self, fe, num_clients: int = 0) -> None:
        """Register a frontend (process pipes, sockets, ...) with the
        service: count its clients towards the ready rule and make sure
        the background flusher runs — frontend submits have no waiting
        thread in this process. The one place the frontend lifecycle
        dance lives, whatever wire the frontend speaks."""
        with self._lock:
            self._clients += num_clients
        self._frontends.append(fe)
        self._loop_needed = True
        if self._started and not self._thread.is_alive():
            self._thread.start()

    def process_frontend(self, ctx, num_clients: int,
                         wire_capacity: Optional[int] = None
                         ) -> "ProcessFrontend":
        fe = ProcessFrontend(self, ctx, num_clients, wire_capacity)
        # clients counted per register() call, not up front
        self.attach_frontend(fe, num_clients=0)
        return fe

    # ------------------------------------------------------------------
    # lifecycle

    def start(self) -> None:
        if not self._started:
            self._started = True
            if self._loop_needed:
                self._thread.start()

    def stop(self) -> None:
        """Shut down: wake every blocked client with a None reply. Safe
        to call from any thread, idempotent. Process frontends are closed
        by the pool that created them (after its children joined)."""
        with self._cond:
            if self._stop.is_set():
                return
            self._stop.set()
            drained = list(self._pending)
            self._pending.clear()
            self._cond.notify_all()
        for p in drained:
            try:
                p.reply_fn(None)
            except Exception:
                pass
        if self._thread.is_alive() and \
                self._thread is not threading.current_thread():
            self._thread.join(timeout=5.0)

    close = stop

    @property
    def closed(self) -> bool:
        return self._stop.is_set()

    def raise_errors(self) -> None:
        if self.errors:
            raise RuntimeError("inference service failed") from \
                self.errors[0]

    # ------------------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            waits = dict(self.wait_hist)
            flushes = (self.flush_full + self.flush_ready +
                       self.flush_timeouts)
            return {
                "flushes": flushes,
                "flush_full": self.flush_full,
                "flush_ready": self.flush_ready,
                "flush_timeout": self.flush_timeouts,
                "batch_size_hist": dict(sorted(self.batch_hist.items())),
                "requests": self.requests,
                "padded_requests": self.padded_requests,
                "frames": self.frames,
                "mean_batch": (self.requests / flushes if flushes else 0.0),
                # bucket k covers [2^(k-1), 2^k) µs; /metrics renders
                # one repro_inference_queue_wait_hist{bucket="k"} per key
                "queue_wait_hist": dict(sorted(waits.items())),
                # quantiles derived from the full-run histogram (bucket
                # upper bounds): same keys the log line always printed
                "queue_wait_ms_p50": _hist_quantile_ms(waits, 0.50),
                "queue_wait_ms_p95": _hist_quantile_ms(waits, 0.95),
                "flush_timeout_s": self.flush_timeout_s,
                "max_batch_requests": self.max_batch_requests,
                "param_version": self._last_version,
            }


class InferenceClient:
    """Thread-mode client: blocking ``infer`` against the in-process
    service (leader-executed flushes — see ``submit_and_wait``). One
    outstanding request per client by construction."""

    def __init__(self, service: InferenceService):
        self._svc = service
        self._paused = False

    def infer(self, data: PyTree) -> Optional[InferenceReply]:
        """None means the service shut down: stop producing."""
        return self._svc.submit_and_wait(data)

    def submit_async(self, data: PyTree) -> Optional[_Waiter]:
        """Pipeline half of ``infer``; pair with ``wait``."""
        return self._svc.submit_async(data)

    def wait(self, w: Optional[_Waiter]) -> Optional[InferenceReply]:
        return None if w is None else self._svc.wait(w)

    def pause(self) -> None:
        """This client has left the request loop (assembly, transport
        backpressure): don't let batches wait for it. Idempotent."""
        if not self._paused:
            self._paused = True
            self._svc._pause()

    def resume(self) -> None:
        if self._paused:
            self._paused = False
            self._svc._resume()

    def close(self) -> None:
        self.resume()       # a paused client must not leak the count
        self._svc._disconnect()


class ProcessFrontend:
    """Parent-side bridge for actor *processes*: serde request frames in
    over one bounded wire, encoded replies out over per-client pipes.

    Mirrors ``ShmTransport``'s shutdown discipline: ``begin_shutdown``
    flips the drain loop to discard so children winding down can always
    flush their queue feeders; ``close`` (after the children are joined)
    tears the wire down.
    """

    def __init__(self, service: InferenceService, ctx, num_clients: int,
                 wire_capacity: Optional[int] = None):
        self._svc = service
        self._ctx = ctx
        self._wire = ctx.Queue(maxsize=wire_capacity or
                               max(2, num_clients * 2))
        self._reply_conns: Dict[int, Any] = {}
        self._paused_cids: set = set()
        self._discard = False
        self._stop_evt = threading.Event()
        self._closed = False
        self._thread = threading.Thread(target=self._loop,
                                        name="inference-frontend",
                                        daemon=True)

    def register(self, client_id: int) -> "PipeInferenceClient":
        """Create the picklable child-side handle for one actor process.
        Call before spawning; the parent keeps the reply send-end."""
        recv_conn, send_conn = self._ctx.Pipe(duplex=False)
        self._reply_conns[client_id] = send_conn
        with self._svc._lock:
            self._svc._clients += 1
        return PipeInferenceClient(client_id, self._wire, recv_conn)

    def start(self) -> None:
        self._thread.start()

    def _reply_fn_for(self, client_id: int
                      ) -> Callable[[Optional[InferenceReply]], None]:
        conn = self._reply_conns[client_id]

        def reply(r: Optional[InferenceReply]) -> None:
            if r is None:
                buf = _STOP_FRAME
            else:
                buf = serde.encode_tree(
                    {"action": np.asarray(r.action),
                     "logprob": np.asarray(r.logprob),
                     "lstm_h": np.asarray(r.lstm_state[0]),
                     "lstm_c": np.asarray(r.lstm_state[1])},
                    meta={"version": int(r.param_version)})
            try:
                conn.send_bytes(buf)
            except (OSError, BrokenPipeError, ValueError):
                pass                    # client exited first: fine

        return reply

    def _loop(self) -> None:
        import queue as stdlib_queue
        while not self._stop_evt.is_set():
            try:
                buf = self._wire.get(timeout=0.1)
            except stdlib_queue.Empty:
                continue
            except (EOFError, OSError):
                break
            try:
                data, meta = serde.decode_tree(buf)   # zero-copy views
            except serde.SerdeError as e:
                self._svc.errors.append(e)
                continue
            cid = int(meta["client"])
            ctl = meta.get("ctl")
            if ctl is not None:
                # pause/resume control frames, tracked per client id so
                # duplicated or reordered hints can never over- or
                # under-count the service's paused total
                if ctl == "pause" and cid not in self._paused_cids:
                    self._paused_cids.add(cid)
                    self._svc._pause()
                elif ctl == "resume" and cid in self._paused_cids:
                    self._paused_cids.discard(cid)
                    self._svc._resume()
                continue
            if self._discard or self._svc.closed:
                # shutdown: keep the wire flowing so child feeders can
                # always flush, and unblock the sender promptly
                self._reply_fn_for(cid)(None)
                continue
            if not self._svc.submit(data, self._reply_fn_for(cid),
                                    float(meta.get("t0",
                                                   time.monotonic()))):
                self._reply_fn_for(cid)(None)

    def begin_shutdown(self) -> None:
        """Flip to discard: the wire keeps draining (a child feeder
        blocked mid-write into a full pipe would hang that child's exit)
        but nothing reaches the service anymore."""
        self._discard = True

    def close(self) -> None:
        """Call after the client processes are joined."""
        if self._closed:
            return
        self._closed = True
        self._discard = True
        self._stop_evt.set()
        self._thread.join(timeout=5.0)
        try:
            while True:
                self._wire.get_nowait()
        except Exception:
            pass
        self._wire.close()
        self._wire.cancel_join_thread()
        for conn in self._reply_conns.values():
            try:
                conn.close()
            except OSError:
                pass


class PipeInferenceClient:
    """Picklable child-side handle: encodes the request pytree, ships it
    over the shared wire, blocks (stop-aware) on its private reply pipe.
    Moves only serde buffers — importable without jax."""

    def __init__(self, client_id: int, wire: Any, conn: Any):
        self._id = client_id
        self._wire = wire
        self._conn = conn
        self._stop: Optional[Any] = None    # bound by the child at start
        self._paused = False

    def bind_stop(self, stop_event: Any) -> None:
        self._stop = stop_event

    def _send_ctl(self, ctl: str, tries: int = 1) -> None:
        import queue as stdlib_queue
        buf = serde.encode_tree(None, meta={"client": self._id,
                                            "ctl": ctl})
        for _ in range(tries):
            if self._stop is not None and self._stop.is_set():
                return
            try:
                self._wire.put(buf, timeout=0.05)
                return
            except stdlib_queue.Full:
                continue
            except Exception:
                return                  # closed wire: shutting down

    def pause(self) -> None:
        """Tell the parent-side service this client left the request
        loop (assembly, trajectory backpressure). Idempotent; a tiny
        meta-only control frame rides the same FIFO wire, so it lands
        in order behind this client's requests. Best-effort — a lost
        pause only costs the others one flush-timeout wait."""
        if not self._paused:
            self._paused = True
            self._send_ctl("pause")

    def resume(self) -> None:
        """Unlike a lost pause, a lost *resume* would leave the service
        under-counting active clients for the rest of the run (chronic
        undersized batches), so it retries hard before giving up."""
        if self._paused:
            self._paused = False
            self._send_ctl("resume", tries=40)

    def submit_async(self, data: PyTree) -> Optional[bool]:
        """Ship the request frame; the reply is read by ``wait``. One
        outstanding request per client (each pipeline stream holds its
        own client, so FIFO on the private reply pipe is enough)."""
        import queue as stdlib_queue
        buf = serde.encode_tree(
            data, meta={"client": self._id, "t0": time.monotonic()})
        while True:
            if self._stop is not None and self._stop.is_set():
                return None
            try:
                self._wire.put(buf, timeout=0.1)
                return True
            except stdlib_queue.Full:
                continue
            except (ValueError, OSError):
                return None

    def wait(self, token: Optional[bool]) -> Optional[InferenceReply]:
        if token is None:
            return None
        while not self._conn.poll(0.1):
            if self._stop is not None and self._stop.is_set():
                return None
        try:
            rbuf = self._conn.recv_bytes()
        except (EOFError, OSError):
            return None
        if rbuf == _STOP_FRAME:
            return None
        tree, meta = serde.decode_tree(rbuf, copy=True)
        return InferenceReply(tree["action"], tree["logprob"],
                              (tree["lstm_h"], tree["lstm_c"]),
                              int(meta["version"]))

    def infer(self, data: PyTree) -> Optional[InferenceReply]:
        return self.wait(self.submit_async(data))

    def close(self) -> None:
        self.resume()
        try:
            self._conn.close()
        except OSError:
            pass
