"""The asynchronous actor-learner runtime (paper §3, for real).

``run_async_training`` stands up N actors — threads (``actor_pool``) or
spawn-based processes (``procpool``) — feeding a bounded backpressured
``Transport`` (in-process deque, or serialized buffers over a
cross-process wire) that one learner loop drains with *dynamic
batching*: up to ``max_batch_trajs`` queued trajectories are stacked
into a single larger learner batch (§3.1's dynamic batching, applied
learner-side), amortising the update's fixed cost over more frames.
Batch sizes are bucketed to powers of two so XLA compiles at most
log2(max_batch_trajs)+1 variants of the train step.

The learner loop itself lives in ``distributed/learner.py`` as the
``Learner`` object (batch collection, donated train step, publish,
telemetry); this module is the *composition root* for the
single-learner shape: build env/params/store/service/transport/pool,
attach them to one ``Learner``, run it. The multi-learner shape —
several ``Learner`` workers, each owning a shard of the actor slots,
exchanging gradients over the framed channel — composes the same
pieces in ``distributed/group.py``.

Actors come in two modes. ``unroll`` (default) gives every actor its
own jitted n-step unroll with a private copy of the params. With
``actor_mode='inference'`` the actors hold no params at all: they step
envs on the host and submit per-step observation batches to one
``InferenceService`` next to the learner — §3.1's dynamic-batched actor
inference, one batched forward on the learner's device instead of N
per-actor forwards.

The learner hot path is tuned three ways:

  donation    ``train_step`` is jitted with ``donate_argnums`` for
              params and opt_state, so XLA updates both in place
              instead of allocating fresh trees every update. Published
              params are a jitted device copy (one params-sized alloc)
              because live references escape to actors / the inference
              service / the serializing param server — a donated buffer
              must have exactly one owner.
  staging     queued host trajectories are stacked into per-bucket
              preallocated, ping-ponged staging buffers and moved with
              one ``device_put`` (no ``np.concatenate`` allocs on the
              consume path).
  kernels     the V-trace implementation resolves 'auto': the fused
              Pallas kernel compiled for real on TPU, scan elsewhere.

Parameters flow learner -> ``ParameterStore`` -> actors; each trajectory
comes back stamped with the parameter version it was acted with, so the
per-trajectory policy lag the learner observes is a **measured** quantity
(`lag = version_now - version_acted`), not a scripted one. The telemetry
snapshot reports the lag histogram alongside actor FPS, learner
updates/sec, queue occupancy, drop/stall counters, and (in inference
mode) the service's batch-size histogram, flush reasons, and queue-wait
quantiles.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

from repro.configs.base import ArchConfig, ImpalaConfig
from repro.data.envs import make_env
from repro.distributed.actor_pool import ActorPool
# re-exports: these lived here before the Learner extraction, and the
# hot-path tests (and MultiTracker consumers) import them from runtime
from repro.distributed.learner import (Learner, MultiTracker,  # noqa: F401
                                       _buckets, _collect_batch,
                                       _device_put_copies, _HostStager,
                                       _stack)
from repro.distributed.paramstore import ParameterStore  # noqa: F401
from repro.distributed.serde import TrajectoryItem  # noqa: F401
from repro.distributed.transport import make_transport

PyTree = Any

ACTOR_MODES = ("unroll", "inference")


def _validate(icfg, max_batch_trajs, actor_backend, actor_mode,
              transport, env_name, spmd_devices: int = 0,
              exchange=None) -> None:
    if not (0.0 <= icfg.replay_fraction < 1.0):
        raise ValueError(f"replay_fraction must be in [0, 1), got "
                         f"{icfg.replay_fraction}")
    if icfg.replay_fraction > 0:
        from repro.core.replay import PRIORITY_MODES

        if icfg.replay_capacity < 1:
            raise ValueError(f"replay_capacity must be >= 1, got "
                             f"{icfg.replay_capacity}")
        if icfg.replay_reuse < 0:
            raise ValueError(f"replay_reuse must be >= 0 (0 = unlimited),"
                             f" got {icfg.replay_reuse}")
        if icfg.replay_priority not in PRIORITY_MODES:
            raise ValueError(f"replay_priority must be one of "
                             f"{PRIORITY_MODES}, got "
                             f"{icfg.replay_priority!r}")
        if icfg.replay_target_period < 1:
            raise ValueError(f"replay_target_period must be >= 1, got "
                             f"{icfg.replay_target_period}")
    if max_batch_trajs < 1:
        raise ValueError(f"max_batch_trajs must be >= 1, got "
                         f"{max_batch_trajs}")
    if actor_backend not in ("thread", "process", "remote"):
        raise ValueError(f"actor_backend must be 'thread', 'process' or "
                         f"'remote', got {actor_backend!r}")
    if actor_mode not in ACTOR_MODES:
        raise ValueError(f"actor_mode must be one of {ACTOR_MODES}, got "
                         f"{actor_mode!r}")
    if actor_backend == "process" and transport != "shm":
        raise ValueError("process actors cannot share live pytrees; use "
                         "transport='shm'")
    if actor_backend == "remote" and transport != "socket":
        raise ValueError("remote actors ship trajectories over TCP; use "
                         "transport='socket'")
    if transport == "socket" and actor_backend != "remote":
        raise ValueError("transport='socket' requires "
                         "actor_backend='remote'")
    if actor_backend == "remote" and not isinstance(env_name, str):
        raise ValueError("remote actors rebuild the env by name; pass "
                         "an env name, not an Env object")
    if spmd_devices:
        if spmd_devices < 1:
            raise ValueError(f"spmd_devices must be >= 1, got "
                             f"{spmd_devices}")
        if exchange is not None:
            raise ValueError("spmd_devices builds its own in-XLA "
                             "CollectiveExchange; it cannot combine "
                             "with a hub/spoke exchange (use a learner "
                             "group OR spmd, not both)")


def _setup(
    env_name: str,
    icfg: ImpalaConfig,
    num_envs: int,
    *,
    num_actors: int = 2,
    actor_backend: str = "thread",
    actor_mode: str = "unroll",
    transport: str = "inproc",
    listen_addr: Optional[Tuple[str, int]] = None,
    spawn_remote: bool = True,
    queue_capacity: int = 8,
    queue_policy: str = "block",
    max_batch_trajs: int = 4,
    batch_linger_s: float = 0.0,
    seed: int = 0,
    arch: Optional[ArchConfig] = None,
    initial_params: Optional[PyTree] = None,
    initial_opt_state: Optional[PyTree] = None,
    start_step: int = 0,
    donate: bool = True,
    infer_flush_timeout_s: float = 0.02,
    infer_max_batch_requests: Optional[int] = None,
    infer_streams: int = 1,
    slot_base: int = 0,
    learner_id: int = 0,
    num_learners: int = 1,
    exchange=None,
    spmd_devices: int = 0,
    peer_addrs=None,
    wire_codec: str = "none",
    vtrace_impl: str = "auto",
    obs=None,
    supervise: bool = False,
    supervisor=None,
    heartbeat_timeout_s: float = 10.0,
    elastic: bool = False,
) -> Learner:
    """Build one learner worker's whole dependency graph — env, params,
    train step, store, optional inference service, transport, actor
    pool — and return the assembled ``Learner``.

    The single-learner ``run_async_training`` calls this with the
    defaults; a ``LearnerGroup`` worker calls it with its shard
    (``slot_base``/``num_actors``), its id, and a ``GradientExchange``.
    Actor slot ids are *global* (``slot_base + i``) in every backend,
    so a given actor's RNG/env-seed stream — ``fold_in(seed,
    actor_id)`` — does not depend on how the slots are sharded over
    learners.

    ``obs`` (an ``repro.obs.ObsConfig``) turns on the flight recorder:
    per-update phase timing, the sampled trajectory tracer (when
    ``trace_path`` is set), and the ``jax.profiler`` window (when
    ``profile_steps`` is set). The learner's metrics registry is shared
    with the transport and the inference service either way, so their
    hot-path counters and the telemetry snapshot read one storage.
    """
    _validate(icfg, max_batch_trajs, actor_backend, actor_mode,
              transport, env_name, spmd_devices=spmd_devices,
              exchange=exchange)
    env = make_env(env_name) if isinstance(env_name, str) else env_name
    if arch is None:
        from repro.core.driver import small_arch
        arch = small_arch(env)

    trace = profile = None
    phase_timing = False
    if obs is not None:
        phase_timing = True
        if obs.trace_path:
            from repro.obs.trace import TraceRecorder
            trace = TraceRecorder()
        if obs.profile_steps:
            from repro.obs.sink import ProfileHook
            profile = ProfileHook(obs.profile_steps, obs.profile_dir)

    if spmd_devices:
        # SPMD learner mode: the Learner sees an *in-XLA* exchange and
        # builds the shard_map train step over a ('data',) mesh of this
        # many local devices (mesh construction — and the
        # device-availability error with its XLA_FLAGS hint — lives in
        # launch/mesh.make_data_mesh). The exchange itself never moves
        # a byte: it delegates version numbers and books round latency.
        from repro.distributed.group import CollectiveExchange
        exchange = CollectiveExchange(spmd_devices, trace=trace)

    learner = Learner(
        arch=arch, icfg=icfg, num_actions=env.num_actions,
        num_envs=num_envs, num_actors=num_actors, transport=None,
        seed=seed, learner_id=learner_id, num_learners=num_learners,
        slot_base=slot_base, actor_mode=actor_mode,
        max_batch_trajs=max_batch_trajs, batch_linger_s=batch_linger_s,
        donate=donate, start_step=start_step,
        initial_params=initial_params,
        initial_opt_state=initial_opt_state, exchange=exchange,
        wire_codec=wire_codec, vtrace_impl=vtrace_impl,
        trace=trace, phase_timing=phase_timing, profile=profile)
    store = learner.store

    # supervision is OPT-IN: without it every fault propagates exactly
    # as before (the chaos tests pin that); with it the pools respawn
    # dead children, the socket transport reaps stale leases, and the
    # supervisor's ledger lands in telemetry (and thus /healthz)
    if supervisor is None and supervise:
        from repro.distributed.supervise import Supervisor
        supervisor = Supervisor()
    learner.supervisor = supervisor
    if supervisor is not None:
        learner.obs_registry.register_producer("supervisor",
                                               supervisor.snapshot)

    service = None
    if actor_mode == "inference":
        from repro.distributed.inference import InferenceService, \
            _pow2_floor
        if infer_streams < 1 or num_envs % infer_streams:
            infer_streams = 1       # pipelining needs an even env split
        service = InferenceService(
            env, arch, icfg, store,
            num_clients=num_actors * infer_streams,
            flush_timeout_s=infer_flush_timeout_s,
            # bucket = one request per *actor*: with pipelined streams
            # this leaves the other stream-group pending, so its flush
            # overlaps the actors' env stepping instead of merging into
            # one monolithic phase
            max_batch_requests=(infer_max_batch_requests or
                                _pow2_floor(num_actors)),
            seed=seed,
            # grouped: the service samples from this learner's folded
            # key (Learner.key = fold_in(key(seed), learner_id)) so no
            # two learners share an action-sampling stream; alone: the
            # plain seed path, byte-identical to what it always was
            rng_key=(learner.key if num_learners > 1 else None),
            registry=learner.obs_registry)
    # one registry per learner worker: the transport's queue/wire
    # counters land in the same storage the snapshot and the /metrics
    # endpoint pull from
    transport_kw = {"registry": learner.obs_registry}
    if transport in ("shm", "socket"):
        # inproc hands live pytrees between threads — nothing to encode,
        # so the codec only reaches transports with a wire
        transport_kw["wire_codec"] = wire_codec
    if transport == "socket":
        transport_kw.update({"listen": listen_addr or ("127.0.0.1", 0),
                             "max_actors": num_actors,
                             "slot_base": slot_base})
        if supervisor is not None:
            # heartbeat liveness + lease reaping + elastic membership
            # only make sense on the networked transport
            transport_kw["heartbeat_timeout_s"] = heartbeat_timeout_s
            transport_kw["elastic"] = elastic
    queue = make_transport(transport, queue_capacity, queue_policy,
                           **transport_kw)
    if supervisor is not None and hasattr(queue, "supervisor"):
        queue.supervisor = supervisor
    learner.queue = queue
    if actor_backend == "remote":
        from repro.distributed.procpool import SocketActorPool
        if peer_addrs is not None:
            queue.peer_addrs = [tuple(a) for a in peer_addrs]
        pool = SocketActorPool(
            env_name, arch, icfg, num_envs, num_actors, store, queue,
            seed=seed, service=service, infer_streams=infer_streams,
            spawn_local=spawn_remote, slot_base=slot_base)
        if not spawn_remote:
            host, port = queue.address
            print(f"learner listening on {host}:{port} — waiting for "
                  f"{num_actors} remote actor(s): "
                  f"PYTHONPATH=src python -m repro.launch.train "
                  f"--connect {host}:{port}", flush=True)
    elif actor_backend == "process":
        from repro.distributed.procpool import ProcessActorPool
        pool = ProcessActorPool(
            env_name if isinstance(env_name, str) else env.name,
            arch, icfg, num_envs, num_actors, store, queue, seed=seed,
            service=service, infer_streams=infer_streams,
            slot_base=slot_base)
    else:
        # thread backend: inference acting is multiplexed by one driver
        # thread (see ActorPool._run_driver), so stream pipelining does
        # not apply
        pool = ActorPool(env, arch, icfg, num_envs, num_actors, store,
                         queue, seed=seed, service=service,
                         slot_base=slot_base)
    if supervisor is not None and hasattr(pool, "attach_supervisor"):
        pool.attach_supervisor(supervisor)
    learner.attach(pool, service)
    return learner


def run_async_training(
    env_name: str,
    icfg: ImpalaConfig,
    num_envs: int,
    steps: int,
    *,
    num_actors: int = 2,
    actor_backend: str = "thread",
    actor_mode: str = "unroll",
    transport: str = "inproc",
    listen_addr: Optional[Tuple[str, int]] = None,
    spawn_remote: bool = True,
    queue_capacity: int = 8,
    queue_policy: str = "block",
    max_batch_trajs: int = 4,
    batch_linger_s: float = 0.0,
    seed: int = 0,
    arch: Optional[ArchConfig] = None,
    warm_buckets: bool = False,
    initial_params: Optional[PyTree] = None,
    initial_opt_state: Optional[PyTree] = None,
    start_step: int = 0,
    donate: bool = True,
    infer_flush_timeout_s: float = 0.02,
    infer_max_batch_requests: Optional[int] = None,
    infer_streams: int = 1,
    wire_codec: str = "none",
    vtrace_impl: str = "auto",
    spmd_devices: int = 0,
    on_update: Optional[Callable[[int, PyTree, Dict, Dict], None]] = None,
    obs=None,
    supervise: bool = False,
    heartbeat_timeout_s: float = 10.0,
    elastic: bool = False,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
) -> Tuple[MultiTracker, Dict, Dict]:
    """Train until ``steps`` total learner updates with real async acting.

    ``actor_backend`` picks where actors live: ``thread`` (workers in
    this interpreter, zero-copy handoff), ``process`` (spawned
    interpreters, each with its own env batch, RNG stream, and jit
    cache), or ``remote`` (actors dial a TCP listen address — the
    paper's cross-machine deployment). ``transport`` picks how
    trajectories travel: ``inproc`` (the live-pytree deque), ``shm``
    (serde-encoded buffers over a cross-process wire), or ``socket``
    (the same buffers as CRC-framed TCP messages). Process actors
    require ``shm``; remote actors require ``socket`` — and
    vice versa. Thread actors accept ``inproc`` or ``shm`` —
    ``thread``+``shm`` drives every byte of the serialization boundary
    without paying process startup, which is exactly what the transport
    tests exploit.

    With the socket transport, ``listen_addr`` is the (host, port) the
    learner binds (default loopback, ephemeral port) and
    ``spawn_remote`` picks between the single-box shape (True: spawn
    ``num_actors`` loopback children that connect like any remote
    machine would) and the real deployment shape (False: listen and
    wait for ``num_actors`` external actors — each remote machine runs
    ``launch.train --connect host:port`` and receives the entire run
    config in the connection handshake).

    ``actor_mode='inference'`` replaces the per-actor jitted unrolls
    with one ``InferenceService`` on the learner's device (conv-LSTM
    agents only): actors become host-side env steppers, observation
    batches are dynamically batched across actors into power-of-two
    buckets with a ``infer_flush_timeout_s`` flush deadline, and the
    telemetry grows an ``inference`` section. Works over both backends:
    thread clients submit in-process, process clients ship serde frames.
    ``infer_streams`` (process backend only; thread acting is
    multiplexed by one driver thread) splits each actor process's env
    batch into that many software-pipelined service streams, so one
    stream's env stepping overlaps the other's in-flight flush; it
    falls back to 1 when ``num_envs`` doesn't divide evenly. Worth it
    only where per-call dispatch is cheap relative to the forward
    (accelerators) — halving the request granularity doubles the
    per-frame dispatch count, which is the binding constraint on small
    CPU hosts (default 1).

    ``donate=True`` (default) jits the train step with
    ``donate_argnums`` for params and opt_state — in-place updates, no
    fresh trees per update. The params the store publishes (and hands to
    ``on_update``) are a jitted device *copy*, so everything outside the
    learner loop keeps working on buffers the learner will never donate.
    Consequently ``initial_params`` is consumed: the caller's tree is
    donated at the first update and must not be reused afterwards.

    ``initial_params`` + ``start_step`` resume from a checkpoint: the
    update counter (and the parameter-store version) continues from
    ``start_step``, so lr schedules and checkpoint numbering line up with
    the interrupted run.

    Returns (tracker, last-update metrics, telemetry). ``on_update`` (if
    given) is called after every learner update with
    ``(update_index, params, metrics, snapshot_fn)`` where ``params`` is
    the published (holdable) snapshot and ``snapshot_fn`` is a zero-arg
    callable producing the telemetry dict on demand — the hook for
    logging and checkpointing without re-implementing the loop.

    ``batch_linger_s`` is the learner's flush deadline: wait up to this
    long for the dynamic batch to fill its largest bucket before
    training on a partial one. Default 0 (greedy take-what's-queued) —
    on a core-starved host the learner's idle wait helps acting but the
    added latency cancels the gain; on many-core hosts a small linger
    trades a bounded staleness increase for fewer, fuller updates.

    ``warm_buckets=True`` pre-compiles the train step for every batch
    bucket before the timed region, so benchmarks measure steady-state
    throughput rather than XLA compilation.

    ``wire_codec`` ('none' | 'bf16' | 'int8') quantizes serialized
    payloads on every wire with one: published parameters, trajectory
    observation leaves (shm and socket transports; inproc hands live
    pytrees around and ignores it), and — under a learner group — the
    gradient-exchange frames. Remote actors learn the codec in the
    connection handshake; a fleet member speaking a codec this build
    doesn't know refuses loudly (``CodecMismatchError``) instead of
    decoding garbage. ``vtrace_impl`` picks the loss's V-trace
    implementation: 'auto' resolves to the fused Pallas loss kernel on
    TPU and the scan path elsewhere; 'fused' / 'pallas' / 'scan' /
    'reference' force one.

    ``spmd_devices`` (N > 0) runs the learner in SPMD mode: one process
    whose train step is a ``shard_map`` over a 1-D ``('data',)`` mesh of
    N local devices — batch sharded on the trajectory axis, params and
    optimizer state replicated, gradients mean-reduced by an in-XLA
    ``psum``. Mathematically the N-learner group update at equal global
    batch, with zero TCP frames in the gradient path. On CPU, grow the
    device pool with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    before the first jax import.

    ``obs`` (an ``repro.obs.ObsConfig``) runs the whole flight recorder
    around the training loop: a ``/metrics`` + ``/healthz`` +
    ``/telemetry`` HTTP endpoint (``metrics_port``; the bound address —
    useful with port 0 — lands in ``obs.bound_address``), a periodic
    JSONL telemetry sink (``sink_path``), sampled per-trajectory
    lifecycle tracing exported as Chrome trace-event JSON
    (``trace_path``/``trace_every``; the sampling rate reaches spawned
    actor children through the ``REPRO_TRACE_EVERY`` env var), and a
    ``jax.profiler`` window over chosen updates (``profile_steps``).
    """
    import os

    learner = _setup(
        env_name, icfg, num_envs,
        num_actors=num_actors, actor_backend=actor_backend,
        actor_mode=actor_mode, transport=transport,
        listen_addr=listen_addr, spawn_remote=spawn_remote,
        queue_capacity=queue_capacity, queue_policy=queue_policy,
        max_batch_trajs=max_batch_trajs, batch_linger_s=batch_linger_s,
        seed=seed, arch=arch, initial_params=initial_params,
        initial_opt_state=initial_opt_state,
        start_step=start_step, donate=donate,
        infer_flush_timeout_s=infer_flush_timeout_s,
        infer_max_batch_requests=infer_max_batch_requests,
        infer_streams=infer_streams, wire_codec=wire_codec,
        vtrace_impl=vtrace_impl, spmd_devices=spmd_devices,
        obs=obs, supervise=supervise,
        heartbeat_timeout_s=heartbeat_timeout_s, elastic=elastic)
    server = sink = None
    prev_trace_env = None
    trace_env_set = False
    if obs is not None:
        if obs.metrics_port is not None:
            from repro.obs.http import MetricsServer
            server = MetricsServer(learner.telemetry_snapshot,
                                   host=obs.metrics_host,
                                   port=obs.metrics_port).start()
            obs.bound_address = server.address
            print(f"[obs] metrics at http://{server.address[0]}:"
                  f"{server.address[1]}/metrics", flush=True)
        if obs.sink_path:
            from repro.obs.sink import JsonlSink
            sink = JsonlSink(obs.sink_path, learner.telemetry_snapshot,
                             obs.sink_interval_s).start()
        if obs.trace_path:
            # actor children (threads read it too) inherit the sampling
            # rate through the environment — no pipe-protocol change
            prev_trace_env = os.environ.get("REPRO_TRACE_EVERY")
            os.environ["REPRO_TRACE_EVERY"] = str(max(1, obs.trace_every))
            trace_env_set = True
    on_ckpt = None
    if ckpt_dir and ckpt_every > 0:
        from repro.checkpoint import checkpoint as ckpt_lib

        def on_ckpt(step, params, opt_state, version):
            # combined tree + fleet extra: a resumed run restores the
            # optimizer moments AND the version stream (and skips dead
            # children's replayed seeds via their restart epochs)
            extra = {"version": int(version), "format": "fleet-v1"}
            sup = getattr(learner, "supervisor", None)
            if sup is not None:
                extra["restart_epochs"] = sup.restart_epochs()
            ckpt_lib.save(ckpt_dir, step,
                          {"params": params, "opt": opt_state},
                          extra=extra)
    try:
        metrics, final_telemetry = learner.run(
            steps, warm_buckets=warm_buckets, on_update=on_update,
            on_checkpoint=on_ckpt, ckpt_every=ckpt_every)
    finally:
        if trace_env_set:
            if prev_trace_env is None:
                os.environ.pop("REPRO_TRACE_EVERY", None)
            else:
                os.environ["REPRO_TRACE_EVERY"] = prev_trace_env
        if obs is not None and obs.trace_path and \
                learner.trace is not None:
            n = learner.trace.export(obs.trace_path)
            print(f"[obs] wrote {n} sampled trajectories -> "
                  f"{obs.trace_path}", flush=True)
        if sink is not None:
            sink.stop()
        if server is not None:
            server.stop()
    return learner.tracker, metrics, final_telemetry
