"""The asynchronous actor-learner runtime (paper §3, for real).

``run_async_training`` stands up N actors — threads (``actor_pool``) or
spawn-based processes (``procpool``) — feeding a bounded backpressured
``Transport`` (in-process deque, or serialized buffers over a
cross-process wire) that one learner loop drains with *dynamic
batching*: up to ``max_batch_trajs`` queued trajectories are stacked
into a single larger learner batch (§3.1's dynamic batching, applied
learner-side), amortising the update's fixed cost over more frames.
Batch sizes are bucketed to powers of two so XLA compiles at most
log2(max_batch_trajs)+1 variants of the train step.

Actors come in two modes. ``unroll`` (default) gives every actor its
own jitted n-step unroll with a private copy of the params. With
``actor_mode='inference'`` the actors hold no params at all: they step
envs on the host and submit per-step observation batches to one
``InferenceService`` next to the learner — §3.1's dynamic-batched actor
inference, one batched forward on the learner's device instead of N
per-actor forwards.

The learner hot path is tuned three ways:

  donation    ``train_step`` is jitted with ``donate_argnums`` for
              params and opt_state, so XLA updates both in place
              instead of allocating fresh trees every update. Published
              params are a jitted device copy (one params-sized alloc)
              because live references escape to actors / the inference
              service / the serializing param server — a donated buffer
              must have exactly one owner.
  staging     queued host trajectories are stacked into per-bucket
              preallocated, ping-ponged staging buffers and moved with
              one ``device_put`` (no ``np.concatenate`` allocs on the
              consume path).
  kernels     the V-trace implementation resolves 'auto': the fused
              Pallas kernel compiled for real on TPU, scan elsewhere.

Parameters flow learner -> ``ParameterStore`` -> actors; each trajectory
comes back stamped with the parameter version it was acted with, so the
per-trajectory policy lag the learner observes is a **measured** quantity
(`lag = version_now - version_acted`), not a scripted one. The telemetry
snapshot reports the lag histogram alongside actor FPS, learner
updates/sec, queue occupancy, drop/stall counters, and (in inference
mode) the service's batch-size histogram, flush reasons, and queue-wait
quantiles.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ImpalaConfig
from repro.core import learner as learner_lib
from repro.core.metrics import EpisodeTracker
from repro.data.envs import make_env
from repro.distributed.actor_pool import ActorPool
from repro.distributed.paramstore import ParameterStore
from repro.distributed.serde import TrajectoryItem
from repro.distributed.transport import make_transport
from repro.models import backbone as bb
from repro.models import common as pcommon

PyTree = Any

ACTOR_MODES = ("unroll", "inference")


class MultiTracker:
    """Episode-return accounting across actor-local env batches."""

    def __init__(self, num_actors: int, num_envs: int):
        self.trackers = [EpisodeTracker(num_envs) for _ in range(num_actors)]
        self._merged: List[float] = []

    def update(self, actor_id: int, rewards, dones) -> None:
        t = self.trackers[actor_id]
        before = len(t.completed)
        t.update(np.asarray(rewards), np.asarray(dones))
        # merge in consumption order so mean_return's last-n window is
        # chronological, not actor-grouped
        self._merged.extend(t.completed[before:])

    @property
    def completed(self) -> List[float]:
        return list(self._merged)

    def mean_return(self, last_n: int = 100) -> float:
        if not self._merged:
            return float("nan")
        return float(np.mean(self._merged[-last_n:]))


def _buckets(max_batch_trajs: int) -> List[int]:
    """Power-of-two stack sizes <= max, descending (compile-count bound)."""
    out, b = [], 1
    while b <= max_batch_trajs:
        out.append(b)
        b *= 2
    return out[::-1]


def _collect_batch(queue, buckets: List[int], first: TrajectoryItem,
                   linger_s: float = 0.0) -> List[TrajectoryItem]:
    """Starting from ``first`` (already popped), drain the queue up to
    the largest bucket, then trim to the largest power-of-two that
    fits — requeueing the overflow *at the front, newest first*, so the
    queue keeps oldest-first order and the next batch starts with the
    trajectories this one could not stack.

    ``linger_s`` is the learner-side flush deadline (the mirror of the
    inference service's): rather than greedily training on whatever is
    queued, wait up to this long for the bucket to fill. A starved
    learner taking singleton batches pays the update's fixed cost per
    trajectory — and on a shared host, those extra updates steal the
    very cores the actors need to refill the queue. The deadline bounds
    the staleness this adds; a full bucket never waits."""
    items = [first]
    deadline = (time.monotonic() + linger_s) if linger_s > 0 else None
    while len(items) < buckets[0]:
        nxt = queue.get_nowait()
        if nxt is None:
            if deadline is None:
                break
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            nxt = queue.get(timeout=remaining)
            if nxt is None:
                break
        items.append(nxt)
    k = next(b for b in buckets if b <= len(items))
    for extra in reversed(items[k:]):
        queue.requeue_front(extra)
    return items[:k]


def _device_put_copies() -> bool:
    """Probe whether ``jax.device_put`` of a host buffer COPIES on this
    backend. The CPU backend zero-copy *aliases* 64-byte-aligned numpy
    buffers (measured on jax 0.4.37, ~half of all allocations): the
    returned "device" array IS the host memory, so a staging buffer
    that produced one can never be rewritten while any consumer might
    still read the batch. Probed on a deterministically 64-aligned
    view so the answer doesn't depend on allocator luck."""
    raw = np.zeros(1024 + 16, np.float32)
    off = (-raw.ctypes.data) % 64 // raw.itemsize
    aligned = raw[off:off + 1024]
    dev = jax.device_put(aligned)
    jax.block_until_ready(dev)
    aligned[0] = 1.0
    return float(np.asarray(dev)[0]) == 0.0


class _HostStager:
    """Per-(bucket, structure) host staging buffers for the learner's
    consume path.

    Serialized transports deliver numpy (often read-only view) leaves;
    stacking ``k`` trajectories with ``np.concatenate`` allocates one
    intermediate per leaf per update. Instead each leaf is written in
    place into a staging buffer and the whole tree moves with one
    ``device_put``. Buffer lifetime depends on what ``device_put``
    does, probed once:

      copies (accelerators)   two preallocated sets per bucket,
          **ping-ponged**, and before a set is *re*-written the batch
          it produced two updates ago is ``block_until_ready``-ed — the
          ping-pong alone only pipelines the async H2D transfer, it is
          not a completion guarantee (by reuse time the transfer has
          long finished, so the block is effectively free).
      aliases (CPU backend)   the "transfer" is free but the batch IS
          the staging memory, with no event to wait on for its
          consumers — so buffers are freshly allocated per stack and
          never reused (same copy count as the concatenate path, still
          a single device_put for the whole tree).
    """

    def __init__(self):
        self._slots: Dict[Any, list] = {}
        self._reuse = _device_put_copies()

    def stack(self, items: List[TrajectoryItem]) -> Optional[PyTree]:
        """Staged stack of >=2 same-shaped numpy trajectories; None if
        the items are not uniform host trees (caller falls back)."""
        datas = [it.data for it in items]
        leaves0, treedef = jax.tree.flatten(datas[0])
        if not all(isinstance(x, np.ndarray) for x in leaves0):
            return None
        shapes = tuple((x.shape, x.dtype.name) for x in leaves0)
        for d in datas[1:]:
            ls, td = jax.tree.flatten(d)
            if td != treedef or \
                    tuple((x.shape, x.dtype.name) for x in ls) != shapes:
                return None                 # ragged: not the hot path
        k = len(items)

        def alloc():
            return [np.empty((x.shape[0] * k,) + x.shape[1:], x.dtype)
                    for x in leaves0]

        if self._reuse:
            key = (k, treedef, shapes)
            slot = self._slots.get(key)
            if slot is None:
                # [two buffer sets, next index, last batch per set]
                slot = self._slots[key] = [(alloc(), alloc()), 0,
                                           [None, None]]
            idx = slot[1]
            bufs = slot[0][idx]
            slot[1] ^= 1
            if slot[2][idx] is not None:
                jax.block_until_ready(slot[2][idx])
        else:
            bufs = alloc()
        for i, d in enumerate(datas):
            for buf, leaf in zip(bufs, jax.tree.leaves(d)):
                b = leaf.shape[0]
                buf[i * b:(i + 1) * b] = leaf
        out = jax.device_put(jax.tree.unflatten(treedef, bufs))
        if self._reuse:
            slot[2][idx] = out
        return out


def _stack(items: List[TrajectoryItem],
           stager: Optional[_HostStager] = None) -> PyTree:
    if len(items) == 1:
        return items[0].data

    if stager is not None:
        staged = stager.stack(items)
        if staged is not None:
            return staged

    def cat(*xs):
        # fallback: host concatenate for numpy leaves (one copy, feeding
        # the jit's host->device transfer), device concatenate otherwise
        if isinstance(xs[0], np.ndarray):
            return np.concatenate(xs, axis=0)
        return jnp.concatenate(xs, axis=0)

    return jax.tree.map(cat, *[it.data for it in items])


def run_async_training(
    env_name: str,
    icfg: ImpalaConfig,
    num_envs: int,
    steps: int,
    *,
    num_actors: int = 2,
    actor_backend: str = "thread",
    actor_mode: str = "unroll",
    transport: str = "inproc",
    listen_addr: Optional[Tuple[str, int]] = None,
    spawn_remote: bool = True,
    queue_capacity: int = 8,
    queue_policy: str = "block",
    max_batch_trajs: int = 4,
    batch_linger_s: float = 0.0,
    seed: int = 0,
    arch: Optional[ArchConfig] = None,
    warm_buckets: bool = False,
    initial_params: Optional[PyTree] = None,
    start_step: int = 0,
    donate: bool = True,
    infer_flush_timeout_s: float = 0.02,
    infer_max_batch_requests: Optional[int] = None,
    infer_streams: int = 1,
    on_update: Optional[Callable[[int, PyTree, Dict, Dict], None]] = None,
) -> Tuple[MultiTracker, Dict, Dict]:
    """Train until ``steps`` total learner updates with real async acting.

    ``actor_backend`` picks where actors live: ``thread`` (workers in
    this interpreter, zero-copy handoff), ``process`` (spawned
    interpreters, each with its own env batch, RNG stream, and jit
    cache), or ``remote`` (actors dial a TCP listen address — the
    paper's cross-machine deployment). ``transport`` picks how
    trajectories travel: ``inproc`` (the live-pytree deque), ``shm``
    (serde-encoded buffers over a cross-process wire), or ``socket``
    (the same buffers as CRC-framed TCP messages). Process actors
    require ``shm``; remote actors require ``socket`` — and
    vice versa. Thread actors accept ``inproc`` or ``shm`` —
    ``thread``+``shm`` drives every byte of the serialization boundary
    without paying process startup, which is exactly what the transport
    tests exploit.

    With the socket transport, ``listen_addr`` is the (host, port) the
    learner binds (default loopback, ephemeral port) and
    ``spawn_remote`` picks between the single-box shape (True: spawn
    ``num_actors`` loopback children that connect like any remote
    machine would) and the real deployment shape (False: listen and
    wait for ``num_actors`` external actors — each remote machine runs
    ``launch.train --connect host:port`` and receives the entire run
    config in the connection handshake).

    ``actor_mode='inference'`` replaces the per-actor jitted unrolls
    with one ``InferenceService`` on the learner's device (conv-LSTM
    agents only): actors become host-side env steppers, observation
    batches are dynamically batched across actors into power-of-two
    buckets with a ``infer_flush_timeout_s`` flush deadline, and the
    telemetry grows an ``inference`` section. Works over both backends:
    thread clients submit in-process, process clients ship serde frames.
    ``infer_streams`` (process backend only; thread acting is
    multiplexed by one driver thread) splits each actor process's env
    batch into that many software-pipelined service streams, so one
    stream's env stepping overlaps the other's in-flight flush; it
    falls back to 1 when ``num_envs`` doesn't divide evenly. Worth it
    only where per-call dispatch is cheap relative to the forward
    (accelerators) — halving the request granularity doubles the
    per-frame dispatch count, which is the binding constraint on small
    CPU hosts (default 1).

    ``donate=True`` (default) jits the train step with
    ``donate_argnums`` for params and opt_state — in-place updates, no
    fresh trees per update. The params the store publishes (and hands to
    ``on_update``) are a jitted device *copy*, so everything outside the
    learner loop keeps working on buffers the learner will never donate.
    Consequently ``initial_params`` is consumed: the caller's tree is
    donated at the first update and must not be reused afterwards.

    ``initial_params`` + ``start_step`` resume from a checkpoint: the
    update counter (and the parameter-store version) continues from
    ``start_step``, so lr schedules and checkpoint numbering line up with
    the interrupted run.

    Returns (tracker, last-update metrics, telemetry). ``on_update`` (if
    given) is called after every learner update with
    ``(update_index, params, metrics, snapshot_fn)`` where ``params`` is
    the published (holdable) snapshot and ``snapshot_fn`` is a zero-arg
    callable producing the telemetry dict on demand — the hook for
    logging and checkpointing without re-implementing the loop.

    ``batch_linger_s`` is the learner's flush deadline: wait up to this
    long for the dynamic batch to fill its largest bucket before
    training on a partial one. Default 0 (greedy take-what's-queued) —
    on a core-starved host the learner's idle wait helps acting but the
    added latency cancels the gain; on many-core hosts a small linger
    trades a bounded staleness increase for fewer, fuller updates.

    ``warm_buckets=True`` pre-compiles the train step for every batch
    bucket before the timed region, so benchmarks measure steady-state
    throughput rather than XLA compilation.
    """
    if icfg.replay_fraction > 0:
        raise ValueError("experience replay is only wired into the sync "
                         "runtime; run with --runtime sync")
    if max_batch_trajs < 1:
        raise ValueError(f"max_batch_trajs must be >= 1, got "
                         f"{max_batch_trajs}")
    if actor_backend not in ("thread", "process", "remote"):
        raise ValueError(f"actor_backend must be 'thread', 'process' or "
                         f"'remote', got {actor_backend!r}")
    if actor_mode not in ACTOR_MODES:
        raise ValueError(f"actor_mode must be one of {ACTOR_MODES}, got "
                         f"{actor_mode!r}")
    if actor_backend == "process" and transport != "shm":
        raise ValueError("process actors cannot share live pytrees; use "
                         "transport='shm'")
    if actor_backend == "remote" and transport != "socket":
        raise ValueError("remote actors ship trajectories over TCP; use "
                         "transport='socket'")
    if transport == "socket" and actor_backend != "remote":
        raise ValueError("transport='socket' requires "
                         "actor_backend='remote'")
    if actor_backend == "remote" and not isinstance(env_name, str):
        raise ValueError("remote actors rebuild the env by name; pass "
                         "an env name, not an Env object")
    env = make_env(env_name) if isinstance(env_name, str) else env_name
    if arch is None:
        from repro.core.driver import small_arch
        arch = small_arch(env)
    specs = bb.backbone_specs(arch, env.num_actions)
    if initial_params is not None:
        params = initial_params
    else:
        params = pcommon.init_params(specs, jax.random.key(seed))
    train_step, opt = learner_lib.build_train_step(arch, icfg,
                                                   env.num_actions)
    if donate:
        train_step = jax.jit(train_step, donate_argnums=(0, 1))
    else:
        train_step = jax.jit(train_step)
    # one jitted whole-tree device copy: the decoupling between the
    # learner's donated working tree and every reference that escapes
    # (store, service, on_update). XLA never aliases non-donated outputs
    # to inputs, so the copy's buffers are independent by construction.
    _snapshot = jax.jit(lambda tree: jax.tree.map(jnp.copy, tree))
    opt_state = opt.init(params)

    store = ParameterStore(_snapshot(params) if donate else params,
                           version=start_step)
    service = None
    if actor_mode == "inference":
        from repro.distributed.inference import InferenceService, \
            _pow2_floor
        if infer_streams < 1 or num_envs % infer_streams:
            infer_streams = 1       # pipelining needs an even env split
        service = InferenceService(
            env, arch, icfg, store,
            num_clients=num_actors * infer_streams,
            flush_timeout_s=infer_flush_timeout_s,
            # bucket = one request per *actor*: with pipelined streams
            # this leaves the other stream-group pending, so its flush
            # overlaps the actors' env stepping instead of merging into
            # one monolithic phase
            max_batch_requests=(infer_max_batch_requests or
                                _pow2_floor(num_actors)),
            seed=seed)
    transport_kw = {}
    if transport == "socket":
        transport_kw = {"listen": listen_addr or ("127.0.0.1", 0),
                        "max_actors": num_actors}
    queue = make_transport(transport, queue_capacity, queue_policy,
                           **transport_kw)
    if actor_backend == "remote":
        from repro.distributed.procpool import SocketActorPool
        pool = SocketActorPool(
            env_name, arch, icfg, num_envs, num_actors, store, queue,
            seed=seed, service=service, infer_streams=infer_streams,
            spawn_local=spawn_remote)
        if not spawn_remote:
            host, port = queue.address
            print(f"learner listening on {host}:{port} — waiting for "
                  f"{num_actors} remote actor(s): "
                  f"PYTHONPATH=src python -m repro.launch.train "
                  f"--connect {host}:{port}", flush=True)
    elif actor_backend == "process":
        from repro.distributed.procpool import ProcessActorPool
        pool = ProcessActorPool(
            env_name if isinstance(env_name, str) else env.name,
            arch, icfg, num_envs, num_actors, store, queue, seed=seed,
            service=service, infer_streams=infer_streams)
    else:
        # thread backend: inference acting is multiplexed by one driver
        # thread (see ActorPool._run_driver), so stream pipelining does
        # not apply
        pool = ActorPool(env, arch, icfg, num_envs, num_actors, store,
                         queue, seed=seed, service=service)
    tracker = MultiTracker(num_actors, num_envs)
    buckets = _buckets(max_batch_trajs)
    stager = _HostStager()
    frames_per_traj = num_envs * icfg.unroll_length

    lag_hist: collections.Counter = collections.Counter()
    batch_hist: collections.Counter = collections.Counter()
    updates = start_step
    frames_consumed = 0
    # the steady-state window opens once every actor has landed at least
    # one trajectory AND the learner is past its compile update — the
    # one-time startup storm (jax import + per-worker XLA compile, paid
    # once per process for the process backend) is not steady state.
    # ``first_t0`` (set after the first update) is the fallback so
    # degenerate runs that end mid-ramp still report an honest rate.
    steady_t0: Optional[float] = None
    steady_updates0 = 0
    steady_frames0 = 0
    first_t0: Optional[float] = None
    first_updates0 = 0
    first_frames0 = 0
    metrics: Dict = {}

    def telemetry_snapshot() -> Dict:
        now = time.monotonic()
        if steady_t0 is not None:
            dt, u0, f0 = now - steady_t0, steady_updates0, steady_frames0
        elif first_t0 is not None:
            dt, u0, f0 = now - first_t0, first_updates0, first_frames0
        else:
            dt, u0, f0 = 0.0, 0, 0
        n_lags = sum(lag_hist.values())
        snap = {
            "learner_updates": updates,
            "frames_consumed": frames_consumed,
            "updates_per_sec": ((updates - u0) / dt if dt > 0 else 0.0),
            "frames_per_sec": ((frames_consumed - f0) / dt
                               if dt > 0 else 0.0),
            "batch_size_hist": dict(batch_hist),
            "lag": {
                "hist": dict(sorted(lag_hist.items())),
                "mean": (sum(k * v for k, v in lag_hist.items()) / n_lags
                         if n_lags else 0.0),
                "max": max(lag_hist) if lag_hist else 0,
                "measured": n_lags,
            },
            "queue": queue.snapshot(),
            "actors": pool.stats(),
            "param_version": store.version,
            "actor_mode": actor_mode,
            "donate": donate,
        }
        if service is not None:
            snap["inference"] = service.snapshot()
        return snap

    if service is not None:
        service.start()
    pool.start()
    try:
        if warm_buckets:
            first = None
            while first is None:
                pool.raise_errors()
                if service is not None:
                    service.raise_errors()
                first = queue.get(timeout=0.5)
            for b in buckets:
                warm = _stack([first] * b) if b > 1 else first.data
                # warm on throwaway copies: with donation the warm call
                # would otherwise consume the real params/opt_state
                out = train_step(_snapshot(params), _snapshot(opt_state),
                                 jnp.int32(0), warm)
                jax.block_until_ready(out[0])   # compile only; discard
            queue.requeue_front(first)

        while updates < steps:
            pool.raise_errors()
            if service is not None:
                service.raise_errors()
            item = queue.get(timeout=0.5)
            if item is None:
                continue
            items = _collect_batch(queue, buckets, item, batch_linger_s)
            k = len(items)

            version_now = store.version
            for it in items:
                lag_hist[version_now - it.param_version] += 1
                tracker.update(it.actor_id, it.data["rewards"],
                               it.data["done"])
            batch = _stack(items, stager)
            params, opt_state, metrics = train_step(
                params, opt_state, jnp.int32(updates), batch)
            published = _snapshot(params) if donate else params
            store.publish(published)
            updates += 1
            frames_consumed += k * frames_per_traj
            batch_hist[k] += 1
            if steady_t0 is None:
                jax.block_until_ready(params)
                if first_t0 is None:
                    # first update includes the learner's jit compile
                    first_t0 = time.monotonic()
                    first_updates0 = updates
                    first_frames0 = frames_consumed
                if all(f > 0 for f in pool.frames):
                    # every worker is past import/compile and producing
                    steady_t0 = time.monotonic()
                    steady_updates0 = updates
                    steady_frames0 = frames_consumed
            if on_update is not None:
                on_update(updates, published, metrics, telemetry_snapshot)
        # snapshot before teardown: pool.join waits out in-flight unrolls
        # and put timeouts, which would silently pad the steady-state dt
        jax.block_until_ready(params)
        final_telemetry = telemetry_snapshot()
    finally:
        # order matters: signal stop (a serializing transport flips to
        # discard mode so producer processes can always flush and exit;
        # the inference service wakes every blocked client with a None
        # reply), join the workers, and only then tear the transport
        # down — a wire closed under a live producer can tear frames
        pool.stop()
        if service is not None:
            service.stop()
        pool.join()
        queue.close()
    pool.raise_errors()
    if service is not None:
        service.raise_errors()
    return tracker, metrics, final_telemetry
