"""The asynchronous actor-learner runtime (paper §3, for real).

``run_async_training`` stands up N actors — threads (``actor_pool``) or
spawn-based processes (``procpool``) — feeding a bounded backpressured
``Transport`` (in-process deque, or serialized buffers over a
cross-process wire) that one learner loop drains with *dynamic
batching*: up to ``max_batch_trajs`` queued trajectories are stacked
into a single larger learner batch (§3.1's dynamic batching, applied
learner-side), amortising the update's fixed cost over more frames.
Batch sizes are bucketed to powers of two so XLA compiles at most
log2(max_batch_trajs)+1 variants of the train step.

Parameters flow learner -> ``ParameterStore`` -> actors; each trajectory
comes back stamped with the parameter version it was acted with, so the
per-trajectory policy lag the learner observes is a **measured** quantity
(`lag = version_now - version_acted`), not a scripted one. The telemetry
snapshot reports the lag histogram alongside actor FPS, learner
updates/sec, queue occupancy, and drop/stall counters.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ImpalaConfig
from repro.core import learner as learner_lib
from repro.core.metrics import EpisodeTracker
from repro.data.envs import make_env
from repro.distributed.actor_pool import ActorPool
from repro.distributed.paramstore import ParameterStore
from repro.distributed.serde import TrajectoryItem
from repro.distributed.transport import make_transport
from repro.models import backbone as bb
from repro.models import common as pcommon

PyTree = Any


class MultiTracker:
    """Episode-return accounting across actor-local env batches."""

    def __init__(self, num_actors: int, num_envs: int):
        self.trackers = [EpisodeTracker(num_envs) for _ in range(num_actors)]
        self._merged: List[float] = []

    def update(self, actor_id: int, rewards, dones) -> None:
        t = self.trackers[actor_id]
        before = len(t.completed)
        t.update(np.asarray(rewards), np.asarray(dones))
        # merge in consumption order so mean_return's last-n window is
        # chronological, not actor-grouped
        self._merged.extend(t.completed[before:])

    @property
    def completed(self) -> List[float]:
        return list(self._merged)

    def mean_return(self, last_n: int = 100) -> float:
        if not self._merged:
            return float("nan")
        return float(np.mean(self._merged[-last_n:]))


def _buckets(max_batch_trajs: int) -> List[int]:
    """Power-of-two stack sizes <= max, descending (compile-count bound)."""
    out, b = [], 1
    while b <= max_batch_trajs:
        out.append(b)
        b *= 2
    return out[::-1]


def _stack(items: List[TrajectoryItem]) -> PyTree:
    if len(items) == 1:
        return items[0].data

    def cat(*xs):
        # serialized transports deliver numpy views: concatenate on the
        # host (one copy, feeding the jit's host->device transfer)
        # instead of converting every leaf to a device array first
        if isinstance(xs[0], np.ndarray):
            return np.concatenate(xs, axis=0)
        return jnp.concatenate(xs, axis=0)

    return jax.tree.map(cat, *[it.data for it in items])


def run_async_training(
    env_name: str,
    icfg: ImpalaConfig,
    num_envs: int,
    steps: int,
    *,
    num_actors: int = 2,
    actor_backend: str = "thread",
    transport: str = "inproc",
    queue_capacity: int = 8,
    queue_policy: str = "block",
    max_batch_trajs: int = 4,
    seed: int = 0,
    arch: Optional[ArchConfig] = None,
    warm_buckets: bool = False,
    initial_params: Optional[PyTree] = None,
    start_step: int = 0,
    on_update: Optional[Callable[[int, PyTree, Dict, Dict], None]] = None,
) -> Tuple[MultiTracker, Dict, Dict]:
    """Train until ``steps`` total learner updates with real async acting.

    ``actor_backend`` picks where actors live: ``thread`` (workers in
    this interpreter, zero-copy handoff) or ``process`` (spawned
    interpreters, each with its own env batch, RNG stream, and jit
    cache). ``transport`` picks how trajectories travel: ``inproc`` (the
    live-pytree deque) or ``shm`` (serde-encoded buffers over a
    cross-process wire). Process actors require the serializing
    transport; thread actors accept either — ``thread``+``shm`` drives
    every byte of the serialization boundary without paying process
    startup, which is exactly what the transport tests exploit.

    ``initial_params`` + ``start_step`` resume from a checkpoint: the
    update counter (and the parameter-store version) continues from
    ``start_step``, so lr schedules and checkpoint numbering line up with
    the interrupted run.

    Returns (tracker, last-update metrics, telemetry). ``on_update`` (if
    given) is called after every learner update with
    ``(update_index, params, metrics, snapshot_fn)`` where ``snapshot_fn``
    is a zero-arg callable producing the telemetry dict on demand — the
    hook for logging and checkpointing without re-implementing the loop.

    ``warm_buckets=True`` pre-compiles the train step for every batch
    bucket before the timed region, so benchmarks measure steady-state
    throughput rather than XLA compilation.
    """
    if icfg.replay_fraction > 0:
        raise ValueError("experience replay is only wired into the sync "
                         "runtime; run with --runtime sync")
    if max_batch_trajs < 1:
        raise ValueError(f"max_batch_trajs must be >= 1, got "
                         f"{max_batch_trajs}")
    if actor_backend not in ("thread", "process"):
        raise ValueError(f"actor_backend must be 'thread' or 'process', "
                         f"got {actor_backend!r}")
    if actor_backend == "process" and transport != "shm":
        raise ValueError("process actors cannot share live pytrees; use "
                         "transport='shm'")
    env = make_env(env_name) if isinstance(env_name, str) else env_name
    if arch is None:
        from repro.core.driver import small_arch
        arch = small_arch(env)
    specs = bb.backbone_specs(arch, env.num_actions)
    if initial_params is not None:
        params = initial_params
    else:
        params = pcommon.init_params(specs, jax.random.key(seed))
    train_step, opt = learner_lib.build_train_step(arch, icfg,
                                                   env.num_actions)
    train_step = jax.jit(train_step)
    opt_state = opt.init(params)

    store = ParameterStore(params, version=start_step)
    queue = make_transport(transport, queue_capacity, queue_policy)
    if actor_backend == "process":
        from repro.distributed.procpool import ProcessActorPool
        pool = ProcessActorPool(
            env_name if isinstance(env_name, str) else env.name,
            arch, icfg, num_envs, num_actors, store, queue, seed=seed)
    else:
        pool = ActorPool(env, arch, icfg, num_envs, num_actors, store,
                         queue, seed=seed)
    tracker = MultiTracker(num_actors, num_envs)
    buckets = _buckets(max_batch_trajs)
    frames_per_traj = num_envs * icfg.unroll_length

    lag_hist: collections.Counter = collections.Counter()
    batch_hist: collections.Counter = collections.Counter()
    updates = start_step
    frames_consumed = 0
    # the steady-state window opens once every actor has landed at least
    # one trajectory AND the learner is past its compile update — the
    # one-time startup storm (jax import + per-worker XLA compile, paid
    # once per process for the process backend) is not steady state.
    # ``first_t0`` (set after the first update) is the fallback so
    # degenerate runs that end mid-ramp still report an honest rate.
    steady_t0: Optional[float] = None
    steady_updates0 = 0
    steady_frames0 = 0
    first_t0: Optional[float] = None
    first_updates0 = 0
    first_frames0 = 0
    metrics: Dict = {}

    def telemetry_snapshot() -> Dict:
        now = time.monotonic()
        if steady_t0 is not None:
            dt, u0, f0 = now - steady_t0, steady_updates0, steady_frames0
        elif first_t0 is not None:
            dt, u0, f0 = now - first_t0, first_updates0, first_frames0
        else:
            dt, u0, f0 = 0.0, 0, 0
        n_lags = sum(lag_hist.values())
        return {
            "learner_updates": updates,
            "frames_consumed": frames_consumed,
            "updates_per_sec": ((updates - u0) / dt if dt > 0 else 0.0),
            "frames_per_sec": ((frames_consumed - f0) / dt
                               if dt > 0 else 0.0),
            "batch_size_hist": dict(batch_hist),
            "lag": {
                "hist": dict(sorted(lag_hist.items())),
                "mean": (sum(k * v for k, v in lag_hist.items()) / n_lags
                         if n_lags else 0.0),
                "max": max(lag_hist) if lag_hist else 0,
                "measured": n_lags,
            },
            "queue": queue.snapshot(),
            "actors": pool.stats(),
            "param_version": store.version,
        }

    pool.start()
    try:
        if warm_buckets:
            first = None
            while first is None:
                pool.raise_errors()
                first = queue.get(timeout=0.5)
            for b in buckets:
                warm = _stack([first] * b) if b > 1 else first.data
                out = train_step(params, opt_state, jnp.int32(0), warm)
                jax.block_until_ready(out[0])   # compile only; discard
            queue.requeue_front(first)

        while updates < steps:
            pool.raise_errors()
            item = queue.get(timeout=0.5)
            if item is None:
                continue
            items = [item]
            while len(items) < buckets[0]:
                nxt = queue.get_nowait()
                if nxt is None:
                    break
                items.append(nxt)
            k = next(b for b in buckets if b <= len(items))
            for extra in reversed(items[k:]):
                queue.requeue_front(extra)      # oldest-first order kept
            items = items[:k]

            version_now = store.version
            for it in items:
                lag_hist[version_now - it.param_version] += 1
                tracker.update(it.actor_id, it.data["rewards"],
                               it.data["done"])
            batch = _stack(items)
            params, opt_state, metrics = train_step(
                params, opt_state, jnp.int32(updates), batch)
            store.publish(params)
            updates += 1
            frames_consumed += k * frames_per_traj
            batch_hist[k] += 1
            if steady_t0 is None:
                jax.block_until_ready(params)
                if first_t0 is None:
                    # first update includes the learner's jit compile
                    first_t0 = time.monotonic()
                    first_updates0 = updates
                    first_frames0 = frames_consumed
                if all(f > 0 for f in pool.frames):
                    # every worker is past import/compile and producing
                    steady_t0 = time.monotonic()
                    steady_updates0 = updates
                    steady_frames0 = frames_consumed
            if on_update is not None:
                on_update(updates, params, metrics, telemetry_snapshot)
        # snapshot before teardown: pool.join waits out in-flight unrolls
        # and put timeouts, which would silently pad the steady-state dt
        jax.block_until_ready(params)
        final_telemetry = telemetry_snapshot()
    finally:
        # order matters: signal stop (a serializing transport flips to
        # discard mode so producer processes can always flush and exit),
        # join the workers, and only then tear the transport down — a
        # wire closed under a live producer can tear frames
        pool.stop()
        pool.join()
        queue.close()
    pool.raise_errors()
    return tracker, metrics, final_telemetry
