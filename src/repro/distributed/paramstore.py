"""Versioned parameter store: the learner publishes, actors pull.

This is the piece that turns policy lag from a scripted fiction
(``core.queue.LagController`` replaying a parameter history) into a
*measured* quantity: every ``pull`` returns ``(params, version)``, the
actor stamps the version into the trajectory it produces, and the learner
computes ``lag = current_version - trajectory.param_version`` at
consumption time — exactly the off-policy gap V-trace corrects (paper §4.2,
Fig. E.1), now emergent from real queueing delays instead of dialled in.

Thread-safety: a single mutex guards the (params, version) pair so a pull
can never observe a torn publish. Params are jax pytrees of immutable
device arrays — publishing swaps the reference, pullers keep whatever
snapshot they grabbed.
"""
from __future__ import annotations

import threading
from typing import Any, Tuple

PyTree = Any


class ParameterStore:
    """Lock-guarded (params, version) cell with monotonically increasing
    versions. Version 0 is the initial (pre-training) parameter set."""

    def __init__(self, params: PyTree, version: int = 0):
        self._lock = threading.Lock()
        self._params = params
        self._version = version
        self.publishes = 0
        self.pulls = 0

    def publish(self, params: PyTree) -> int:
        """Install new params; returns the new version."""
        with self._lock:
            self._params = params
            self._version += 1
            self.publishes += 1
            return self._version

    def pull(self) -> Tuple[PyTree, int]:
        """Returns the current (params, version) snapshot."""
        with self._lock:
            self.pulls += 1
            return self._params, self._version

    @property
    def version(self) -> int:
        with self._lock:
            return self._version
