"""Versioned parameter store: the learner publishes, actors pull.

This is the piece that turns policy lag from a scripted fiction
(``core.queue.LagController`` replaying a parameter history) into a
*measured* quantity: every ``pull`` returns ``(params, version)``, the
actor stamps the version into the trajectory it produces, and the learner
computes ``lag = current_version - trajectory.param_version`` at
consumption time — exactly the off-policy gap V-trace corrects (paper §4.2,
Fig. E.1), now emergent from real queueing delays instead of dialled in.

Thread-safety: a single mutex guards the (params, version) pair so a pull
can never observe a torn publish. Params are jax pytrees of immutable
device arrays — publishing swaps the reference, pullers keep whatever
snapshot they grabbed.

Actor *processes* can't share the live pytree, so the store also has a
serialized subscribe path: ``pull_serialized(have_version)`` returns a
serde-encoded buffer only when something newer than ``have_version``
exists (else None — a cheap "you're current"). The encode is done at
most once per published version and cached, so N subscribing actors cost
one device->host copy per update, not N.
"""
from __future__ import annotations

import threading
from typing import Any, Optional, Tuple

PyTree = Any


class ParameterStore:
    """Lock-guarded (params, version) cell with monotonically increasing
    versions. Version 0 is the initial (pre-training) parameter set."""

    def __init__(self, params: PyTree, version: int = 0,
                 wire_codec: str = "none"):
        from repro.distributed import serde
        self._lock = threading.Lock()
        self._params = params
        self._version = version
        self.wire_codec = serde.check_codec(wire_codec)
        self.publishes = 0
        self.pulls = 0
        self.serialized_pulls = 0
        self.serialized_encodes = 0
        self.serialized_wire_bytes = 0   # last encode: bytes on the wire
        self.serialized_raw_bytes = 0    # last encode: raw leaf bytes
        self._ser_cache: Optional[Tuple[int, bytes]] = None

    def publish(self, params: PyTree) -> int:
        """Install new params; returns the new version."""
        with self._lock:
            self._params = params
            self._version += 1
            self.publishes += 1
            return self._version

    def publish_at(self, params: PyTree, version: int) -> int:
        """Versioned publish *delegation*: install new params at an
        externally assigned version. In a learner group the designated
        publisher (the gradient-exchange hub) numbers the rounds, and
        every learner's store publishes at exactly that number — so
        actors pulling from different learners observe one consistent,
        monotonic version stream. Non-monotonic delegation is a
        protocol bug, not a race to paper over: it raises."""
        with self._lock:
            if version <= self._version:
                raise ValueError(
                    f"delegated version {version} is not newer than "
                    f"current {self._version} (versions must be "
                    f"monotonic)")
            self._params = params
            self._version = version
            self.publishes += 1
            return self._version

    def pull(self) -> Tuple[PyTree, int]:
        """Returns the current (params, version) snapshot."""
        with self._lock:
            self.pulls += 1
            return self._params, self._version

    def pull_serialized(self, have_version: int = -1
                        ) -> Optional[Tuple[bytes, int]]:
        """Returns (encoded params, version) if anything newer than
        ``have_version`` is published, else None. Encoding happens
        outside the lock (device->host copy can be slow) and is cached
        per version; concurrent first-pulls may both encode — idempotent,
        last writer wins."""
        with self._lock:
            self.serialized_pulls += 1
            version = self._version
            if version <= have_version:
                return None
            params = self._params
            cached = self._ser_cache
        if cached is not None and cached[0] == version:
            return cached[1], version
        from repro.distributed import serde
        buf = serde.encode_tree(params, codec=self.wire_codec)
        self.serialized_encodes += 1
        with self._lock:
            # don't regress the cache if a newer version was encoded in
            # the meantime
            if self._ser_cache is None or self._ser_cache[0] <= version:
                self._ser_cache = (version, buf)
            self.serialized_wire_bytes = len(buf)
            self.serialized_raw_bytes = serde.tree_nbytes(params)
        return buf, version

    @property
    def version(self) -> int:
        with self._lock:
            return self._version
