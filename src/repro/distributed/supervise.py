"""Supervision for the self-healing fleet: restart policies and the
bookkeeping every pool/group layer shares.

At the paper's scale preemption is the steady state, not the exception
(§1: "scales to thousands of machines"), so a child death is an event
to *absorb*, not an error to propagate: the pools ask a ``Supervisor``
whether a dead child may be respawned, the socket transport reports
reaped slot leases here, and the group runner reports hub failovers.
One object owns the counts so telemetry (and ``/healthz``) can show the
exact number of restarts / failovers / lease reaps a run survived.

Deliberately jax-free at import (it runs in the group parent and in
pool threads before any worker touches a device) and free of any
repro import: plain stdlib so every layer can depend on it.

Restart discipline
------------------
* **Budget**: at most ``max_restarts`` deaths per child within a
  sliding ``window_s`` window. A child over budget is *exhausted*:
  ``record_death`` returns None, the pool falls back to raising, and
  ``/healthz`` goes unhealthy.
* **Backoff**: restart ``epoch`` N waits ``base * 2**(N-1)`` seconds,
  capped at ``cap``, with deterministic per-(child, epoch) jitter so a
  mass preemption doesn't respawn the whole fleet in phase.
* **Seed folding**: a respawned child must NOT replay the RNG stream
  of its dead predecessor (its env state is gone; replaying actions
  against fresh envs would correlate trajectories). ``fold_restart_seed``
  derives a deterministic per-epoch seed the spawn entrypoints fold
  exactly like the original one.
"""
from __future__ import annotations

import random
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

_SEED_FOLD_PRIME = 1_000_003


class KillSafeEvent:
    """Minimal ``multiprocessing.Event`` stand-in that survives a
    SIGKILLed sharer.

    ``mp.Event`` guards its flag with a semaphore lock and every
    ``is_set()`` acquires it — so a child killed mid-check dies
    *holding* the lock, and the parent's own teardown ``set()`` then
    blocks forever. A fleet that expects its children to be killed
    needs a stop flag with nothing a corpse can hold: one shared byte,
    read and written without locking (a single aligned byte store is
    atomic on every platform we target). ``wait`` polls — fine for a
    once-per-run latch, wrong for anything high-frequency.

    Implements exactly the surface the runtime uses of the real thing:
    ``is_set`` / ``set`` / ``clear`` / ``wait(timeout)``. Picklable to
    ``spawn`` children as a ``Process`` arg like any sharedctypes
    object.
    """

    _POLL_S = 0.05

    def __init__(self, ctx: Optional[Any] = None):
        if ctx is None:
            import multiprocessing as mp
            ctx = mp.get_context("spawn")
        self._flag = ctx.RawValue("b", 0)

    def is_set(self) -> bool:
        return self._flag.value != 0

    def set(self) -> None:
        self._flag.value = 1

    def clear(self) -> None:
        self._flag.value = 0

    def wait(self, timeout: Optional[float] = None) -> bool:
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while not self.is_set():
            if deadline is None:
                time.sleep(self._POLL_S)
                continue
            left = deadline - time.monotonic()
            if left <= 0:
                return False
            time.sleep(min(self._POLL_S, left))
        return True


def fold_restart_seed(seed: int, epoch: int) -> int:
    """Deterministic seed for restart epoch ``epoch`` of a child that
    was originally seeded with ``seed``. Epoch 0 is the first spawn and
    returns ``seed`` unchanged (bit-compatible with unsupervised runs)."""
    if epoch == 0:
        return int(seed)
    return int(seed + epoch * _SEED_FOLD_PRIME) % (2 ** 31 - 1)


@dataclass(frozen=True)
class RestartPolicy:
    """Max restarts per sliding window + exponential backoff with
    jitter. ``jitter`` is the max relative widening of a delay (0.5 =
    up to +50%)."""
    max_restarts: int = 5
    window_s: float = 60.0
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    jitter: float = 0.5

    def delay_s(self, key: str, epoch: int) -> float:
        base = min(self.backoff_base_s * (2 ** max(epoch - 1, 0)),
                   self.backoff_cap_s)
        # deterministic per-(child, epoch) jitter: reproducible runs,
        # but no two children share a phase
        u = random.Random(f"{key}:{epoch}").random()
        return base * (1.0 + self.jitter * u)


@dataclass(frozen=True)
class RestartDecision:
    """What the supervisor grants for one death: the new restart epoch
    and the earliest monotonic time the respawn may happen."""
    key: str
    epoch: int
    delay_s: float
    not_before: float


class _Child:
    __slots__ = ("epoch", "deaths", "pending")

    def __init__(self) -> None:
        self.epoch = 0                      # restart epoch of the LIVE child
        self.deaths: deque = deque()        # monotonic death times (window)
        self.pending: Optional[RestartDecision] = None


class Supervisor:
    """Thread-safe restart ledger shared by every supervised layer.

    The supervisor does not spawn anything itself — pools own their
    spawn mechanics. The contract is:

      decision = sup.record_death("actor-3")    # None => exhausted
      ... wait until decision.not_before, respawn with
      fold_restart_seed(seed, decision.epoch) ...
      sup.note_restarted("actor-3")

    ``record_lease_reap`` / ``record_failover`` + ``note_failover_done``
    are the transport's and group runner's hooks into the same ledger.
    """

    def __init__(self, policy: Optional[RestartPolicy] = None,
                 name: str = "supervisor"):
        self.policy = policy or RestartPolicy()
        self.name = name
        self._lock = threading.Lock()
        self._children: Dict[str, _Child] = {}
        self.restarts = 0
        self.failovers = 0
        self.lease_reaps = 0
        self._restart_in_flight = 0
        self._failover_in_flight = 0
        self._exhausted: List[str] = []

    # -- restart ----------------------------------------------------------

    def record_death(self, key: str) -> Optional[RestartDecision]:
        """A child died. Returns the restart grant, or None when the
        child's restart budget is exhausted (caller should raise)."""
        now = time.monotonic()
        with self._lock:
            child = self._children.setdefault(key, _Child())
            if child.pending is not None:
                return child.pending        # death already being handled
            child.deaths.append(now)
            while child.deaths and \
                    now - child.deaths[0] > self.policy.window_s:
                child.deaths.popleft()
            if len(child.deaths) > self.policy.max_restarts:
                if key not in self._exhausted:
                    self._exhausted.append(key)
                return None
            epoch = child.epoch + 1
            delay = self.policy.delay_s(key, epoch)
            decision = RestartDecision(key=key, epoch=epoch,
                                       delay_s=delay,
                                       not_before=now + delay)
            child.pending = decision
            self._restart_in_flight += 1
            return decision

    def note_restarted(self, key: str) -> None:
        """The respawn happened: the grant is consumed and counted."""
        with self._lock:
            child = self._children.get(key)
            if child is None or child.pending is None:
                return
            child.epoch = child.pending.epoch
            child.pending = None
            self.restarts += 1
            self._restart_in_flight = max(self._restart_in_flight - 1, 0)

    def child_epoch(self, key: str) -> int:
        with self._lock:
            child = self._children.get(key)
            return child.epoch if child is not None else 0

    def restart_epochs(self) -> Dict[str, int]:
        """Live restart epoch per child that ever died (for checkpoint
        extra: a resumed run must not replay a dead child's seeds)."""
        with self._lock:
            return {k: c.epoch for k, c in self._children.items()
                    if c.epoch > 0 or c.pending is not None}

    # -- failover / lease reaps -------------------------------------------

    def record_failover(self) -> None:
        with self._lock:
            self._failover_in_flight += 1

    def note_failover_done(self) -> None:
        with self._lock:
            if self._failover_in_flight > 0:
                self._failover_in_flight -= 1
                self.failovers += 1

    def record_lease_reap(self, key: str) -> None:
        with self._lock:
            self.lease_reaps += 1

    # -- introspection ----------------------------------------------------

    @property
    def exhausted(self) -> List[str]:
        with self._lock:
            return list(self._exhausted)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "restarts": self.restarts,
                "failovers": self.failovers,
                "lease_reaps": self.lease_reaps,
                "restart_in_flight": self._restart_in_flight,
                "failover_in_flight": self._failover_in_flight,
                "restarts_exhausted": list(self._exhausted),
                "epochs": {k: c.epoch
                           for k, c in self._children.items()
                           if c.epoch > 0},
            }
