"""Sharded multi-learner training: a ``LearnerGroup`` of N learner
worker processes, each owning a disjoint shard of the actor slots,
exchanging gradients over the framed channel (paper §3's *several
learners, each owning a shard of actors* — in the modern data-parallel
form TorchBeast and IMPACT use: every learner holds a full parameter
replica, backward passes run on local shards' trajectories, and the
replicas stay identical by applying the exchanged mean gradient).

Topology (single box today; the exchange is an interface so a
``jax.distributed`` mesh backend can slot in later)::

     actors 0..a-1          actors a..n-1          (global slot ids:
        |  shard 0             |  shard 1           fold_in(seed, id)
        v                      v                    unchanged by the
    +-----------+         +-----------+             sharding)
    | learner 0 |         | learner 1 |
    | Transport |         | Transport |   per-learner transport,
    |  Learner  |         |  Learner  |   dynamic batching, telemetry
    +-----+-----+         +-----+-----+
          |   grads (KIND_GRAD frames)
          +<------------------>+          synchronous all-reduce over
          |   mean + version       one CRC-framed TCP channel
          v (KIND_GRAD_MEAN)
     designated publisher (learner 0 == the hub) numbers the rounds;
     every learner's ParameterStore publishes at that version, so all
     actors observe ONE monotonic version stream.

The exchange is *synchronous with a stale-grad drop rule*: the hub
waits for every live learner's round-t contribution, but never longer
than ``stale_after_s`` — past the deadline it reduces over what
arrived, and a contribution landing after its round was reduced is
dropped (counted, never averaged). The laggard still receives (and
applies) every broadcast mean in order, so its replica follows the
group's parameter trajectory exactly; it just stops influencing it
until it catches up. A learner whose connection dies leaves the
expected set entirely.

Module-level imports stay jax-free (like the transports): worker
processes import this module before paying the jax import, and the
import-guard test pins the edge.
"""
from __future__ import annotations

import collections
import json
import multiprocessing as mp
import socket
import threading
import time
import traceback
from multiprocessing import connection as mp_connection
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.distributed import serde
from repro.distributed.socket_transport import (CTRL_BYE, CTRL_REFUSED,
                                                CTRL_STOP, Disconnected,
                                                FrameChannel, KIND_CTRL,
                                                KIND_GRAD,
                                                KIND_GRAD_MEAN,
                                                KIND_HELLO)
from repro.distributed.supervise import (KillSafeEvent, RestartPolicy,
                                         Supervisor, fold_restart_seed)

PyTree = Any
Address = Tuple[str, int]

# how many reduced rounds the hub keeps for replay to late-registering
# spokes (a spoke that dialed after its round was reduced still needs
# the mean to stay on the group's parameter trajectory)
MEAN_HISTORY = 64


def shard_slots(num_actors: int, num_learners: int
                ) -> List[Tuple[int, int]]:
    """Split ``num_actors`` global slots into ``num_learners``
    contiguous shards: [(base, count), ...]. The remainder goes to the
    first learners, and every learner gets at least one slot."""
    if num_learners < 1:
        raise ValueError(f"num_learners must be >= 1, got {num_learners}")
    if num_actors < num_learners:
        raise ValueError(f"need at least one actor per learner: "
                         f"{num_actors} actors < {num_learners} learners")
    base_count, extra = divmod(num_actors, num_learners)
    shards, base = [], 0
    for k in range(num_learners):
        count = base_count + (1 if k < extra else 0)
        shards.append((base, count))
        base += count
    return shards


# ---------------------------------------------------------------------------
# gradient exchange


class GradientExchange:
    """What sits between a ``Learner``'s backward pass and its
    optimizer: ``allreduce(leaves, round_idx)`` takes the local
    gradient leaves (numpy, tree-flatten order) and returns the
    group-mean leaves plus the *delegated publish version* for the
    round — or None when the group is shutting down.

    Implementations: ``NullExchange`` (one learner, identity),
    ``GradHub``/``SpokeExchange`` (synchronous mean over CRC-framed
    TCP, single box or LAN). A ``jax.distributed`` mesh backend slots
    in here later — the ``Learner`` never knows which it got.
    """

    learner_id: int = 0
    num_learners: int = 1

    def allreduce(self, leaves: List[np.ndarray], round_idx: int
                  ) -> Optional[Tuple[List[np.ndarray], int]]:
        raise NotImplementedError

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": type(self).__name__,
                "learner_id": self.learner_id,
                "num_learners": self.num_learners}

    def close(self) -> None:
        pass


class NullExchange(GradientExchange):
    """The degenerate one-learner exchange: the mean of one gradient is
    itself, and the delegated version is simply round + 1. Exists so a
    group of one exercises the exact worker/exchange plumbing a bigger
    group uses."""

    def __init__(self):
        self.rounds = 0

    def allreduce(self, leaves, round_idx):
        self.rounds += 1
        return list(leaves), round_idx + 1

    def snapshot(self):
        snap = super().snapshot()
        snap["rounds"] = self.rounds
        return snap


class CollectiveExchange(GradientExchange):
    """The in-XLA exchange behind the single-process SPMD learner mode
    (``--learner-mode spmd``): the gradient mean is a ``lax.pmean``
    fused INSIDE the shard_map train step (device-to-device collective,
    zero host round-trips, zero TCP frames), so by the time
    ``allreduce`` is called the reduction has already been dispatched.
    What remains of the contract is exactly what it implements: the
    delegated publish version (``round_idx + 1``, the same numbering
    the hub assigns) and the round accounting — so stale-drop/publish/
    version semantics upstream are untouched and ``NullExchange`` /
    ``GradHub`` stay selectable through the same ``Learner`` seam.

    ``in_xla = True`` is the marker the ``Learner`` keys on to swap the
    split grad/apply path for the fused shard_map step. The learner
    reports each round's measured latency (dispatch -> collective
    complete) via ``observe_round_s``; the snapshot exposes it as a
    power-of-two-µs histogram (bucket k covers [2^(k-1), 2^k) µs, the
    ``inference.queue_wait_hist`` convention) plus mean ms, under
    ``exchange_backend: "collective"`` — and deliberately has no
    ``bytes_in``/``bytes_out``: nothing crosses a wire.
    """

    in_xla = True

    def __init__(self, num_devices: int, trace=None):
        if num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got "
                             f"{num_devices}")
        self.num_devices = num_devices
        self.rounds = 0
        self.trace = trace
        self._round_hist: collections.Counter = collections.Counter()
        self._round_s_total = 0.0

    def allreduce(self, leaves, round_idx):
        self.rounds += 1
        return list(leaves), round_idx + 1

    def observe_round_s(self, elapsed_s: float,
                        round_idx: int = 0) -> None:
        """Fold one round's measured step+collective latency into the
        histogram (and the exchange trace row, reusing the hub's span
        export: no hub_wait/broadcast phases exist in-XLA, so the whole
        round renders as one reduce span)."""
        self._round_hist[max(0, int(elapsed_s * 1e6)).bit_length()] += 1
        self._round_s_total += elapsed_s
        if self.trace is not None:
            now = time.monotonic()
            self.trace.record_exchange_round(
                round_idx, enter=now - elapsed_s, gathered=now - elapsed_s,
                reduced=now, done=now)

    def snapshot(self):
        snap = super().snapshot()
        snap["exchange_backend"] = "collective"
        snap["devices"] = self.num_devices
        snap["rounds"] = self.rounds
        snap["round_us_hist"] = dict(sorted(self._round_hist.items()))
        snap["round_ms_mean"] = (1e3 * self._round_s_total / self.rounds
                                 if self.rounds else 0.0)
        return snap


def _mean_leaves(contribs: Dict[int, List[np.ndarray]]
                 ) -> List[np.ndarray]:
    """Element-wise mean over per-learner leaf lists, accumulated in a
    fixed (sorted-by-learner) order so the result is deterministic."""
    order = sorted(contribs)
    n = len(order)
    out = []
    for i, first in enumerate(contribs[order[0]]):
        acc = np.array(first, dtype=first.dtype, copy=True)
        for k in order[1:]:
            acc += contribs[k][i]
        if np.issubdtype(acc.dtype, np.floating) or \
                acc.dtype.name == "bfloat16":
            acc /= acc.dtype.type(n)
        out.append(acc)
    return out


class GradHub(GradientExchange):
    """The designated publisher's side of the exchange (learner 0): a
    tiny accept loop speaking the serde frame format. Spokes HELLO in
    with their learner id, ship ``KIND_GRAD`` frames per round, and
    receive the reduced ``KIND_GRAD_MEAN`` (which carries the round's
    delegated publish version). The CRC framing and torn-tail
    discipline are exactly the trajectory wire's — a flipped bit in a
    gradient frame is a loud ``SerdeError``, never a silently corrupted
    update."""

    def __init__(self, num_learners: int, *,
                 listen: Address = ("127.0.0.1", 0),
                 stale_after_s: float = 180.0,
                 stop_event: Optional[Any] = None,
                 wire_codec: str = serde.DEFAULT_CODEC,
                 hub_id: int = 0,
                 start_round: int = -1,
                 dead: Any = (),
                 hold_disconnected: bool = False,
                 trace: Optional[Any] = None):
        """``hub_id`` is this hub's own learner id (nonzero after a
        failover promotes a former spoke). ``start_round`` seeds the
        stale-round watermark: a hub taking over mid-run at round t
        passes ``t - 1`` so round t is reducible but nothing older is.
        ``dead`` pre-marks learner ids known lost (the failed-over hub)
        so rounds never wait on them; a reborn id that re-registers is
        un-marked. ``hold_disconnected`` (supervised runs) keeps a
        disconnected spoke in the round's wait set until the stale
        deadline instead of excluding it outright — under supervision a
        vanished spoke is *being respawned*, and a hub that raced
        through the remaining rounds alone would finish and unbind
        before the reborn spoke ever redials. ``trace`` (a
        ``TraceRecorder``) records per-round hub_wait/reduce/broadcast
        spans when set."""
        if num_learners < 1:
            raise ValueError("num_learners must be >= 1")
        if not 0 <= hub_id < num_learners:
            raise ValueError(f"hub_id must be in [0, {num_learners}), "
                             f"got {hub_id}")
        self.learner_id = self.hub_id = int(hub_id)
        self.num_learners = num_learners
        self.stale_after_s = stale_after_s
        self.trace = trace
        # KIND_GRAD_MEAN broadcasts are encoded with this; spokes must
        # announce the same codec in their HELLO or be refused — a
        # mixed-codec group would average quantization error unevenly
        # across replicas, which the digest check would only catch at
        # the very end of the run
        self.wire_codec = serde.check_codec(wire_codec)
        self._ext_stop = stop_event
        self._stop = threading.Event()
        self._cond = threading.Condition()
        # round -> learner_id -> leaves (hub's own contribution included)
        self._contrib: Dict[int, Dict[int, List[np.ndarray]]] = {}
        self._done_round = int(start_round)
        self._spokes: Dict[int, FrameChannel] = {}
        self._dead: set = {int(d) for d in dead} - {self.hub_id}
        self._hold_disconnected = bool(hold_disconnected)
        self._mean_history: "collections.OrderedDict[int, bytes]" = \
            collections.OrderedDict()
        # telemetry
        self.rounds = 0
        self.stale_dropped = 0
        self.partial_rounds = 0     # rounds reduced past the deadline
        self.reduce_wait_s = 0.0
        self.bytes_in = 0
        self.bytes_out = 0

        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind(tuple(listen))
        self._lsock.listen(max(4, num_learners))
        self._lsock.settimeout(0.2)
        self.address: Address = self._lsock.getsockname()[:2]
        self._threads: List[threading.Thread] = []
        acceptor = threading.Thread(target=self._accept_loop,
                                    name="grad-hub-accept", daemon=True)
        acceptor.start()
        self._threads.append(acceptor)

    # ------------------------------------------------------------------

    def _stopped(self) -> bool:
        return self._stop.is_set() or (
            self._ext_stop is not None and self._ext_stop.is_set())

    def _accept_loop(self) -> None:
        while not self._stopped():
            try:
                sock, _peer = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(target=self._spoke_entry, args=(sock,),
                                 name="grad-hub-spoke", daemon=True)
            t.start()
            self._threads.append(t)

    def _spoke_entry(self, sock: socket.socket) -> None:
        chan = FrameChannel(sock)
        deadline = time.monotonic() + 10.0
        try:
            kind, _stream, payload = chan.recv(
                stop=lambda: self._stopped() or
                time.monotonic() > deadline)
            hello = json.loads(payload.decode("utf-8"))
            lid = int(hello["learner_id"])
            if kind != KIND_HELLO or hello.get("role") != "learner" or \
                    not 0 <= lid < self.num_learners or \
                    lid == self.hub_id:
                chan.close()
                return
            spoke_codec = hello.get("wire_codec", serde.DEFAULT_CODEC)
            if spoke_codec != self.wire_codec:
                # refuse with a named reason, not a silent close: the
                # spoke raises CodecMismatchError instead of diagnosing
                # a generic "hub connection lost"
                msg = (CTRL_REFUSED + b" wire_codec mismatch: hub "
                       b"speaks " + self.wire_codec.encode() +
                       b", spoke announced " + str(spoke_codec).encode())
                bye = time.monotonic() + 5.0
                chan.send(KIND_CTRL, lid, msg,
                          stop=lambda: self._stopped() or
                          time.monotonic() > bye)
                chan.close()
                return
        except (Disconnected, serde.SerdeError, ValueError, KeyError):
            chan.close()
            return
        with self._cond:
            old = self._spokes.get(lid)
            if old is not None:
                old.close()
            self._spokes[lid] = chan
            self._dead.discard(lid)
            # replay reduced rounds the spoke missed: it must apply
            # every mean in order to stay on the group's trajectory
            history = list(self._mean_history.items())
        for _rnd, buf in history:
            chan.send(KIND_GRAD_MEAN, lid, buf, stop=self._stopped)
        self._spoke_reader(lid, chan)

    def _spoke_reader(self, lid: int, chan: FrameChannel) -> None:
        while not self._stopped():
            try:
                kind, _stream, payload = chan.recv(stop=self._stopped)
            except (Disconnected, serde.SerdeError):
                break
            if kind == KIND_CTRL and payload == CTRL_BYE:
                break
            if kind != KIND_GRAD:
                continue
            try:
                leaves, meta = serde.decode_grads(payload)
            except serde.SerdeError:
                break                   # desynced/corrupt: drop the conn
            rnd = int(meta.get("round", -1))
            with self._cond:
                self.bytes_in += len(payload)
                if rnd <= self._done_round:
                    # the stale-grad drop rule: this round was already
                    # reduced (deadline passed or the spoke re-sent) —
                    # averaging it in now would desynchronise replicas
                    self.stale_dropped += 1
                else:
                    self._contrib.setdefault(rnd, {})[lid] = leaves
                    self._cond.notify_all()
        chan.close()
        with self._cond:
            if self._spokes.get(lid) is chan:
                # unsupervised, a vanished spoke is dead: exclude it so
                # rounds stop waiting. Supervised, it is being respawned
                # — keep it in the wait set (the stale deadline still
                # bounds every round) so the reborn spoke finds the hub
                # alive, replays the means it missed, and rejoins.
                if not self._hold_disconnected:
                    self._dead.add(lid)
                self._cond.notify_all()

    # ------------------------------------------------------------------

    def allreduce(self, leaves, round_idx):
        t0 = time.monotonic()
        deadline = t0 + self.stale_after_s
        with self._cond:
            self._contrib.setdefault(round_idx, {})[self.hub_id] = \
                list(leaves)
            while True:
                got = self._contrib.get(round_idx, {})
                expected = self.num_learners - len(self._dead)
                if len(got) >= expected:
                    break
                if self._stopped():
                    return None
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    # reduce over what arrived — the hub's own
                    # contribution is always present, so the mean is
                    # over >= 1 learner
                    self.partial_rounds += 1
                    break
                self._cond.wait(min(0.2, remaining))
            got = self._contrib.pop(round_idx)
            # prune older rounds a laggard may have half-delivered
            for rnd in [r for r in self._contrib if r <= round_idx]:
                self.stale_dropped += len(self._contrib.pop(rnd))
            self._done_round = round_idx
        t_gathered = time.monotonic()
        mean = _mean_leaves(got)
        version = round_idx + 1
        buf = serde.encode_grads(mean, round_idx=round_idx,
                                 learner_id=self.hub_id, version=version,
                                 codec=self.wire_codec)
        if self.wire_codec != "none":
            # lossy codec: spokes apply the DECODED broadcast, so the
            # hub must apply the same round-tripped values — applying
            # its pre-quantization mean would silently fork the
            # replicas (caught by the params_digest identity check)
            mean, _meta = serde.decode_grads(buf, copy=True)
        t_reduced = time.monotonic()
        with self._cond:
            # history BEFORE the spoke snapshot, under ONE lock: a
            # spoke registering concurrently either lands in this
            # snapshot (gets the broadcast) or registers after the
            # history insert (gets the replay) — there is no window in
            # which it misses both
            self._mean_history[round_idx] = buf
            while len(self._mean_history) > MEAN_HISTORY:
                self._mean_history.popitem(last=False)
            spokes = dict(self._spokes)
        for lid, chan in sorted(spokes.items()):
            # bounded send: a wedged spoke (suspended process, full TCP
            # buffer) must not stall the whole group's round — past the
            # deadline the channel is closed, its reader marks the
            # spoke dead, and later rounds stop expecting it. A healthy
            # link takes the frame instantly; the laggard that wakes up
            # redials nothing (spokes don't reconnect) and its learner
            # fails loudly, which beats a silent group-wide hang.
            send_deadline = time.monotonic() + 5.0
            if chan.send(KIND_GRAD_MEAN, lid, buf,
                         stop=lambda d=send_deadline:
                         self._stopped() or time.monotonic() > d):
                self.bytes_out += len(buf)
            elif not self._stopped():
                chan.close()
        self.rounds += 1
        t_done = time.monotonic()
        self.reduce_wait_s += t_done - t0
        if self.trace is not None:
            self.trace.record_exchange_round(
                round_idx, enter=t0, gathered=t_gathered,
                reduced=t_reduced, done=t_done)
        return mean, version

    # ------------------------------------------------------------------

    def snapshot(self):
        snap = super().snapshot()
        with self._cond:
            snap.update({
                "hub_id": self.hub_id,
                "rounds": self.rounds,
                "wire_codec": self.wire_codec,
                "stale_dropped": self.stale_dropped,
                "partial_rounds": self.partial_rounds,
                "dead_learners": sorted(self._dead),
                "reduce_wait_ms_mean": (1e3 * self.reduce_wait_s /
                                        self.rounds if self.rounds
                                        else 0.0),
                "bytes_in": self.bytes_in,
                "bytes_out": self.bytes_out,
            })
        return snap

    def close(self):
        if self._stop.is_set():
            return
        self._stop.set()
        with self._cond:
            spokes = dict(self._spokes)
            self._cond.notify_all()
        for _lid, chan in spokes.items():
            # unblock spokes waiting on a mean that will never come
            deadline = time.monotonic() + 2.0
            chan.send(KIND_CTRL, 0, CTRL_STOP,
                      stop=lambda d=deadline: time.monotonic() > d)
            chan.close()
        try:
            self._lsock.close()
        except OSError:
            pass
        for t in self._threads:
            t.join(timeout=5.0)


class SpokeExchange(GradientExchange):
    """A non-publisher learner's side: dial the hub, ship local
    gradients, block for the round's mean (synchronous — the learner
    applies nothing it did not receive from the hub, which is what
    keeps the replicas bit-identical)."""

    def __init__(self, address: Address, learner_id: int,
                 num_learners: int, *,
                 stop_event: Optional[Any] = None,
                 dial_timeout_s: float = 120.0,
                 reply_timeout_s: float = 600.0,
                 wire_codec: str = serde.DEFAULT_CODEC):
        if not 0 < learner_id < num_learners:
            raise ValueError(f"spoke learner_id must be in "
                             f"(0, {num_learners}), got {learner_id}")
        self.learner_id = learner_id
        self.num_learners = num_learners
        self.wire_codec = serde.check_codec(wire_codec)
        self._addr = tuple(address)
        self._ext_stop = stop_event
        self._stop = threading.Event()
        self._reply_timeout_s = reply_timeout_s
        self._cond = threading.Condition()
        self._means: Dict[int, Tuple[List[np.ndarray], int]] = {}
        self._hub_gone = False
        self._refused: Optional[str] = None
        # telemetry
        self.rounds = 0
        self.reduce_wait_s = 0.0
        self.bytes_in = 0
        self.bytes_out = 0

        deadline = time.monotonic() + dial_timeout_s
        delay = 0.05
        chan = None
        while not self._stopped():
            try:
                sock = socket.create_connection(self._addr, timeout=1.0)
                chan = FrameChannel(sock)
                hello = json.dumps({"role": "learner",
                                    "learner_id": learner_id,
                                    "wire_codec": self.wire_codec}
                                   ).encode()
                if chan.send(KIND_HELLO, learner_id, hello,
                             stop=self._stopped):
                    break
                chan.close()
                chan = None
            except OSError:
                pass
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"could not reach gradient-exchange hub at "
                    f"{self._addr[0]}:{self._addr[1]} within "
                    f"{dial_timeout_s:.0f}s")
            time.sleep(delay)
            delay = min(delay * 2, 1.0)
        if chan is None:
            raise RuntimeError("stopped before the gradient-exchange "
                               "hub handshake completed")
        self._chan = chan
        self._reader = threading.Thread(target=self._read_loop,
                                        name="grad-spoke-reader",
                                        daemon=True)
        self._reader.start()

    # ------------------------------------------------------------------

    def _stopped(self) -> bool:
        return self._stop.is_set() or (
            self._ext_stop is not None and self._ext_stop.is_set())

    def _read_loop(self) -> None:
        while not self._stopped():
            try:
                kind, _stream, payload = self._chan.recv(
                    stop=self._stopped)
            except (Disconnected, serde.SerdeError):
                break
            if kind == KIND_CTRL and payload == CTRL_STOP:
                break
            if kind == KIND_CTRL and payload.startswith(CTRL_REFUSED):
                with self._cond:
                    self._refused = (
                        payload[len(CTRL_REFUSED):].strip().decode(
                            "utf-8", "replace") or "hub refused spoke")
                break
            if kind != KIND_GRAD_MEAN:
                continue
            try:
                leaves, meta = serde.decode_grads(payload, copy=True)
            except serde.SerdeError:
                break
            with self._cond:
                self.bytes_in += len(payload)
                self._means[int(meta["round"])] = (
                    leaves, int(meta["version"]))
                self._cond.notify_all()
        with self._cond:
            self._hub_gone = True
            self._cond.notify_all()

    def abort_wait(self) -> None:
        """Mark the hub lost from the outside (the supervision layer
        learned of its death before TCP did): wakes a blocked
        ``allreduce`` so failover can proceed instead of riding out
        the full reply timeout."""
        with self._cond:
            self._hub_gone = True
            self._cond.notify_all()

    # ------------------------------------------------------------------

    def allreduce(self, leaves, round_idx):
        t0 = time.monotonic()
        buf = serde.encode_grads(list(leaves), round_idx=round_idx,
                                 learner_id=self.learner_id,
                                 codec=self.wire_codec)
        sent = self._chan.send(KIND_GRAD, self.learner_id, buf,
                               stop=self._stopped)
        # a failed send is NOT fatal by itself: the hub's stale rule
        # reduces without us and still broadcasts the mean we need
        if sent:
            self.bytes_out += len(buf)
        deadline = t0 + self._reply_timeout_s
        with self._cond:
            while round_idx not in self._means:
                if self._stopped():
                    return None
                if self._refused is not None:
                    raise serde.CodecMismatchError(
                        f"gradient-exchange hub refused learner "
                        f"{self.learner_id}: {self._refused}")
                if self._hub_gone:
                    raise RuntimeError(
                        "gradient-exchange hub connection lost "
                        f"(learner {self.learner_id}, round {round_idx})")
                if any(r > round_idx for r in self._means):
                    # a LATER round's mean has arrived without ours:
                    # the hub reduced past us and our round fell out
                    # of its replay history (or the frame was lost).
                    # A replayed backlog can deliver briefly out of
                    # order, so give in-flight frames a short grace —
                    # then fail fast and diagnosable instead of
                    # stalling out the full reply timeout
                    deadline = min(deadline, time.monotonic() + 10.0)
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise RuntimeError(
                        f"no gradient mean for round {round_idx} within "
                        f"{self._reply_timeout_s:.0f}s (learner "
                        f"{self.learner_id}"
                        + (", later rounds HAVE arrived — the round "
                           "was evicted from the hub's replay history"
                           if any(r > round_idx for r in self._means)
                           else "") + ")")
                self._cond.wait(min(0.2, remaining))
            mean, version = self._means.pop(round_idx)
            # prune means for rounds we will never request again
            for rnd in [r for r in self._means if r < round_idx]:
                del self._means[rnd]
        self.rounds += 1
        self.reduce_wait_s += time.monotonic() - t0
        return mean, version

    # ------------------------------------------------------------------

    def snapshot(self):
        snap = super().snapshot()
        with self._cond:
            snap.update({
                "rounds": self.rounds,
                "wire_codec": self.wire_codec,
                "hub": list(self._addr),
                "hub_gone": self._hub_gone,
                "reduce_wait_ms_mean": (1e3 * self.reduce_wait_s /
                                        self.rounds if self.rounds
                                        else 0.0),
                "bytes_in": self.bytes_in,
                "bytes_out": self.bytes_out,
            })
        return snap

    def close(self):
        if self._stop.is_set():
            return
        self._stop.set()
        if not self._chan.dead:
            deadline = time.monotonic() + 2.0
            self._chan.send(KIND_CTRL, 0, CTRL_BYE,
                            stop=lambda: time.monotonic() > deadline)
        self._chan.close()
        with self._cond:
            self._cond.notify_all()
        self._reader.join(timeout=5.0)


class ResilientExchange(GradientExchange):
    """The self-healing wrapper a *supervised* group worker puts around
    its exchange. The bare ``SpokeExchange`` keeps its fail-fast
    contract (hub gone => RuntimeError) — this class is where that
    error becomes a recoverable event:

    * ``allreduce`` catches the hub-gone/timeout error and blocks
      (bounded by ``failover_deadline_s``) for the parent's failover
      verdict, delivered through the worker's control thread via
      ``begin_failover`` / ``set_hub``.
    * If THIS learner is the promoted one, it builds a new ``GradHub``
      continuing at ``start_round = round_idx - 1`` (so the in-flight
      round reduces on the new hub) with the dead hub pre-marked, and
      reports the address via ``on_promoted`` (the worker ships it up
      the pipe; the parent relays it to the surviving spokes).
    * Otherwise it redials the relayed address as a fresh spoke and
      retries the same round — the round number never skips, so the
      group's monotonic version stream continues across the failover.
    * Past the deadline it degrades to *solo* training: the mean of a
      group of one, version ``round + 1`` continuity, and a loud
      ``degraded_solo`` telemetry flag (``/healthz`` shows degraded).

    Codec mismatches still raise (that is a config bug, not a fault).
    """

    def __init__(self, inner: GradientExchange, learner_id: int,
                 num_learners: int, *,
                 stop_event: Optional[Any] = None,
                 failover_deadline_s: float = 20.0,
                 stale_after_s: float = 180.0,
                 wire_codec: str = serde.DEFAULT_CODEC,
                 on_promoted=None,
                 initial_dead: Any = ()):
        self.learner_id = learner_id
        self.num_learners = num_learners
        self.wire_codec = serde.check_codec(wire_codec)
        self._inner = inner
        self._ext_stop = stop_event
        self._stop = threading.Event()
        self._cond = threading.Condition()
        self._failover_deadline_s = failover_deadline_s
        self._stale_after_s = stale_after_s
        self._on_promoted = on_promoted
        self._dead_ids = {int(d) for d in initial_dead}
        self._promote = False
        self._new_hub: Optional[Address] = None
        self.failovers = 0
        self.degraded_solo = False
        self.solo_rounds = 0

    # ------------------------------------------------------------------

    def _stopped(self) -> bool:
        return self._stop.is_set() or (
            self._ext_stop is not None and self._ext_stop.is_set())

    # control plane — called from the worker's parent-pipe reader thread

    def begin_failover(self, new_hub_id: int,
                       dead_id: Optional[int] = None) -> None:
        """The parent named a new hub. Arm the swap and wake a blocked
        allreduce (the inner spoke may not have noticed the death)."""
        with self._cond:
            if dead_id is not None:
                self._dead_ids.add(int(dead_id))
            self._promote = int(new_hub_id) == self.learner_id
            self._new_hub = None
            self._cond.notify_all()
        poke = getattr(self._inner, "abort_wait", None)
        if poke is not None:
            poke()

    def set_hub(self, addr: Address) -> None:
        """The promoted hub's address arrived (relayed by the parent)."""
        with self._cond:
            self._new_hub = tuple(addr)
            self._cond.notify_all()

    # ------------------------------------------------------------------

    def allreduce(self, leaves, round_idx):
        while not self._stopped():
            if self.degraded_solo:
                # the mean of a group of one; version stream continues
                self.solo_rounds += 1
                return list(leaves), round_idx + 1
            inner = self._inner
            try:
                out = inner.allreduce(leaves, round_idx)
            except serde.CodecMismatchError:
                raise               # config bug: never retried
            except RuntimeError:
                out = None          # hub gone / round evicted / timeout
                if self._stopped():
                    return None
            else:
                if out is not None:
                    return out
                if self._stopped():
                    return None
            if not self._swap(round_idx):
                if self._stopped():
                    return None
                self.degraded_solo = True
        return None

    def _swap(self, round_idx: int) -> bool:
        """Wait (bounded) for the failover verdict, then become the new
        hub or redial it. False => deadline passed, caller degrades."""
        try:
            self._inner.close()
        except Exception:
            pass
        deadline = time.monotonic() + self._failover_deadline_s
        while not self._stopped():
            with self._cond:
                promote, addr = self._promote, self._new_hub
                if not promote and addr is None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                    self._cond.wait(min(0.2, remaining))
                    continue
                self._promote = False
                self._new_hub = None
                dead = set(self._dead_ids)
            if promote:
                # ResilientExchange only exists in supervised runs, so a
                # promoted hub always holds disconnected spokes for the
                # respawner rather than writing them off
                hub = GradHub(self.num_learners, hub_id=self.learner_id,
                              start_round=round_idx - 1,
                              stale_after_s=self._stale_after_s,
                              stop_event=self._ext_stop,
                              wire_codec=self.wire_codec,
                              dead=dead, hold_disconnected=True)
                self._inner = hub
                self.failovers += 1
                if self._on_promoted is not None:
                    self._on_promoted(hub.address)
                return True
            try:
                spoke = SpokeExchange(
                    tuple(addr), self.learner_id, self.num_learners,
                    stop_event=self._ext_stop,
                    dial_timeout_s=max(1.0,
                                       deadline - time.monotonic()),
                    reply_timeout_s=max(60.0, 4 * self._stale_after_s),
                    wire_codec=self.wire_codec)
            except RuntimeError:
                continue            # not up yet (or died again): wait on
            self._inner = spoke
            self.failovers += 1
            return True
        return False

    # ------------------------------------------------------------------

    def snapshot(self):
        snap = self._inner.snapshot()
        snap.update({
            "resilient": True,
            "learner_id": self.learner_id,
            "failovers": self.failovers,
            "degraded_solo": self.degraded_solo,
            "solo_rounds": self.solo_rounds,
        })
        return snap

    def close(self):
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        self._inner.close()


# ---------------------------------------------------------------------------
# merged telemetry


def merge_telemetry(per_learner: Dict[int, Dict[str, Any]], *,
                    publisher: int = 0,
                    group_extra: Optional[Dict[str, Any]] = None
                    ) -> Dict[str, Any]:
    """Fold N per-learner telemetry snapshots into one group snapshot.

    Each learner's full snapshot survives untouched under
    ``learners.learner_<k>`` (so queue / inference / per-actor loss
    sections can never collide across learners), and the top level
    carries the aggregates a dashboard wants: summed frames and actor
    counters, the merged lag histogram, the publisher's update counter
    and rates, and a ``group`` section with exchange health."""
    if not per_learner:
        raise ValueError("merge_telemetry needs at least one snapshot")
    pub = per_learner.get(publisher,
                          per_learner[min(per_learner)])
    lag_hist: collections.Counter = collections.Counter()
    frames = 0
    fps = 0.0
    stale = 0
    actors = {"num_actors": 0, "frames": 0, "trajectories": 0,
              "rejected": 0, "actor_fps": 0.0,
              "backend": pub.get("actors", {}).get("backend", "?"),
              "per_learner_trajectories": {}}
    for k, snap in sorted(per_learner.items()):
        frames += snap.get("frames_consumed", 0)
        fps += snap.get("frames_per_sec", 0.0)
        for lag, n in snap.get("lag", {}).get("hist", {}).items():
            lag_hist[int(lag)] += n
        stale += snap.get("exchange", {}).get("stale_dropped", 0)
        a = snap.get("actors", {})
        actors["num_actors"] += a.get("num_actors", 0)
        actors["frames"] += a.get("frames", 0)
        actors["trajectories"] += a.get("trajectories", 0)
        actors["rejected"] += a.get("rejected", 0)
        actors["actor_fps"] += a.get("actor_fps", 0.0)
        actors["per_learner_trajectories"][f"learner_{k}"] = \
            a.get("trajectories", 0)
    n_lags = sum(lag_hist.values())
    replay = _merge_replay(per_learner)
    out = {
        "group": {
            "num_learners": len(per_learner),
            "publisher": publisher,
            # the SPMD learner surfaces the same section labelled
            # "collective"; dashboards key on the backend, not topology
            "exchange_backend": "hub_spoke",
            "stale_dropped": stale,
        },
        "learners": {f"learner_{k}": snap
                     for k, snap in sorted(per_learner.items())},
        "learner_updates": pub.get("learner_updates", 0),
        "frames_consumed": frames,
        "updates_per_sec": pub.get("updates_per_sec", 0.0),
        "frames_per_sec": fps,
        "param_version": max(s.get("param_version", 0)
                             for s in per_learner.values()),
        "lag": {
            "hist": dict(sorted(lag_hist.items())),
            "mean": (sum(k * v for k, v in lag_hist.items()) / n_lags
                     if n_lags else 0.0),
            "max": max(lag_hist) if lag_hist else 0,
            "measured": n_lags,
        },
        "actors": actors,
        "actor_mode": pub.get("actor_mode", "unroll"),
        "donate": pub.get("donate", True),
    }
    if replay is not None:
        out["replay"] = replay
    if group_extra:
        out["group"].update(group_extra)
    return out


def _merge_replay(per_learner: Dict[int, Dict[str, Any]]
                  ) -> Optional[Dict[str, Any]]:
    """Aggregate the per-learner ``replay`` sections (present only when
    replay is enabled): counters and histograms sum across replicas,
    the reuse ratio is recomputed from the summed frame counts, and
    config echoes (capacity, reuse_limit, ...) come from the first
    reporting learner — every replica runs the same config."""
    snaps = [s["replay"] for _k, s in sorted(per_learner.items())
             if isinstance(s.get("replay"), dict)]
    if not snaps:
        return None
    first = snaps[0]
    out = {k: first.get(k) for k in
           ("capacity", "reuse_limit", "priority_mode", "fraction",
            "fresh_max", "target_period")}
    for k in ("occupancy", "added", "sampled", "displaced",
              "evicted_fifo", "evicted_exhausted", "starved",
              "frames_trained", "trained_frames_per_sec",
              "target_syncs"):
        out[k] = sum(s.get(k, 0) for s in snaps)
    for hk in ("priority_hist",):
        h: collections.Counter = collections.Counter()
        for s in snaps:
            for b, n in s.get(hk, {}).items():
                h[int(b)] += n
        out[hk] = dict(sorted(h.items()))
    stale: collections.Counter = collections.Counter()
    for s in snaps:
        for b, n in s.get("staleness", {}).get("hist", {}).items():
            stale[int(b)] += n
    n_stale = sum(stale.values())
    out["staleness"] = {
        "hist": dict(sorted(stale.items())),
        "mean": (sum(k * v for k, v in stale.items()) / n_stale
                 if n_stale else 0.0),
        "max": max(stale) if stale else 0,
        "measured": n_stale,
    }
    frames = sum(s.get("frames_consumed", 0) for s in per_learner.values())
    out["reuse_ratio"] = (out["frames_trained"] / frames if frames else 0.0)
    return out


class GroupTracker:
    """The group's merged episode-return history: per-learner
    (completion time, return) streams interleaved chronologically, with
    the same ``completed`` / ``mean_return`` surface ``MultiTracker``
    exposes — callers of ``run_group_training`` see the tracker they
    always saw."""

    def __init__(self, timed_returns: List[Tuple[float, float]]):
        ordered = sorted(timed_returns, key=lambda p: p[0])
        self._completed = [r for _t, r in ordered]

    @property
    def completed(self) -> List[float]:
        return list(self._completed)

    def mean_return(self, last_n: int = 100) -> float:
        if not self._completed:
            return float("nan")
        return float(np.mean(self._completed[-last_n:]))


# ---------------------------------------------------------------------------
# learner worker (spawn target)


def _learner_worker(learner_id: int, conn, stop_event,
                    spec: Dict[str, Any]) -> None:
    """One learner worker process: build the exchange FIRST (cheap,
    jax-free — so the hub is listening and every spoke registered
    while jax is still importing), then the full worker graph via
    ``runtime._setup``, run the ``Learner``, ship the results up the
    pipe. Exits via ``os._exit`` with an honest code (XLA's C++
    teardown can abort an otherwise clean interpreter exit —
    see ``netserve.remote_actor_child``)."""
    import os

    status = 1
    try:
        num_learners = int(spec["num_learners"])
        wire_codec = spec.get("wire_codec", serde.DEFAULT_CODEC)
        supervise = bool(spec.get("supervise", False))
        hub_id = int(spec.get("hub_id", 0))
        start_step = int(spec["start_step"])
        initial_params = initial_opt = None
        resume = spec.get("resume")
        if resume is not None:
            # respawn / group resume: start from the checkpointed
            # replica + optimizer state at its published version, so
            # the version stream continues monotonically
            initial_params, _ = serde.decode_tree(resume["params"],
                                                  copy=True)
            initial_opt, _ = serde.decode_tree(resume["opt"], copy=True)
            start_step = int(resume["version"])
        # publisher duty follows the hub (a promotion flips it mid-run)
        state = {"publisher": learner_id == hub_id}
        pend_dead: set = set()
        exchange = None
        resilient = None

        def _build_hub(dead=()):
            return GradHub(num_learners, hub_id=learner_id,
                           start_round=start_step - 1,
                           stale_after_s=spec["stale_after_s"],
                           stop_event=stop_event,
                           wire_codec=wire_codec, dead=dead,
                           hold_disconnected=supervise)

        if num_learners > 1:
            if learner_id == hub_id:
                exchange = _build_hub()
                conn.send(("hub", list(exchange.address)))
            else:
                while exchange is None:
                    msg = conn.recv()   # parent relays the hub address
                    if msg[0] == "failover" and supervise:
                        # the hub died before it ever bound: the parent
                        # re-elected pre-start
                        if len(msg) > 2 and msg[2] is not None:
                            pend_dead.add(int(msg[2]))
                        if int(msg[1]) == learner_id:
                            hub_id = learner_id
                            state["publisher"] = True
                            exchange = _build_hub(dead=pend_dead)
                            conn.send(("hub", list(exchange.address)))
                        continue
                    if msg[0] != "hub" or msg[1] is None:
                        raise RuntimeError("no gradient-exchange hub "
                                           "address (hub worker failed?)")
                    exchange = SpokeExchange(
                        tuple(msg[1]), learner_id, num_learners,
                        stop_event=stop_event,
                        reply_timeout_s=max(600.0,
                                            4 * spec["stale_after_s"]),
                        wire_codec=wire_codec)
        # num_learners == 1: no exchange at all — the worker then runs
        # the exact fused donated train step run_async_training runs,
        # which is what the first-train-step bit-match test pins

        if supervise and exchange is not None:
            def _on_promoted(addr):
                state["publisher"] = True
                try:
                    conn.send(("hub", list(addr)))
                except (OSError, BrokenPipeError):
                    pass

            resilient = ResilientExchange(
                exchange, learner_id, num_learners,
                stop_event=stop_event,
                failover_deadline_s=float(
                    spec.get("failover_deadline_s", 20.0)),
                stale_after_s=spec["stale_after_s"],
                wire_codec=wire_codec,
                on_promoted=_on_promoted,
                initial_dead=pend_dead)
            exchange = resilient

            def _control():
                # the parent's only post-handshake messages are
                # failover verdicts and relayed hub addresses; the main
                # thread never recv()s again, so this thread owns the
                # read side of the pipe from here on
                while not stop_event.is_set():
                    try:
                        if not conn.poll(0.2):
                            continue
                        msg = conn.recv()
                    except (EOFError, OSError):
                        return
                    if msg[0] == "failover":
                        resilient.begin_failover(
                            int(msg[1]),
                            dead_id=(int(msg[2])
                                     if len(msg) > 2 and
                                     msg[2] is not None else None))
                    elif msg[0] == "hub" and msg[1] is not None:
                        resilient.set_hub(tuple(msg[1]))

            threading.Thread(target=_control, name="group-control",
                             daemon=True).start()

        from repro.distributed import runtime

        base, count = spec["shards"][learner_id]
        listen_addrs = spec.get("listen_addrs")
        learner = runtime._setup(
            spec["env"], spec["icfg"], spec["num_envs"],
            num_actors=count,
            actor_backend=spec["actor_backend"],
            actor_mode=spec["actor_mode"],
            transport=spec["transport"],
            listen_addr=(tuple(listen_addrs[learner_id])
                         if listen_addrs else None),
            spawn_remote=spec["spawn_remote"],
            queue_capacity=spec["queue_capacity"],
            queue_policy=spec["queue_policy"],
            max_batch_trajs=spec["max_batch_trajs"],
            batch_linger_s=spec["batch_linger_s"],
            seed=spec["seed"], arch=spec["arch"],
            start_step=start_step, donate=spec["donate"],
            initial_params=initial_params,
            initial_opt_state=initial_opt,
            supervise=supervise,
            infer_flush_timeout_s=spec["infer_flush_timeout_s"],
            infer_streams=spec["infer_streams"],
            slot_base=base, learner_id=learner_id,
            num_learners=num_learners, exchange=exchange,
            peer_addrs=spec.get("peer_addrs"),
            wire_codec=wire_codec,
            vtrace_impl=spec.get("vtrace_impl", "auto"))

        tel_every = int(spec.get("telemetry_every", 0))
        tel_interval = float(spec.get("telemetry_interval_s", 0.0))
        # every supervised worker keeps the cadence (promotion may hand
        # it publisher duty mid-run); unsupervised non-publishers skip
        ckpt_every = (int(spec.get("ckpt_every", 0))
                      if supervise or learner_id == hub_id else 0)
        ckpt_full = bool(spec.get("ckpt_full", False))
        last_tel = [time.monotonic()]

        def on_update(step, params, _metrics, snapshot_fn):
            # step-counted sends drive on_progress logging; time-based
            # sends keep the parent's live /metrics aggregation fresh
            # even when a learner's update rate crawls
            due = tel_every and step % tel_every == 0
            if not due and tel_interval:
                due = time.monotonic() - last_tel[0] >= tel_interval
            if due:
                last_tel[0] = time.monotonic()
                try:
                    conn.send(("telemetry", snapshot_fn()))
                except (OSError, BrokenPipeError):
                    pass
            if ckpt_every and step % ckpt_every == 0 and \
                    state["publisher"]:
                # periodic checkpoint stream: the publisher ships its
                # replica up the pipe (replicas are identical, one copy
                # suffices) so the parent can save mid-run state — a
                # crash at step N loses at most ckpt_every rounds
                import jax
                host = jax.tree.map(np.asarray, params)
                try:
                    if ckpt_full:
                        # full group checkpoint: params + optimizer
                        # state + published version, what a respawned
                        # spoke (or a --resume run) starts from
                        conn.send(("ckpt", step,
                                   int(learner.store.version),
                                   serde.encode_tree(host),
                                   serde.encode_tree(
                                       learner.opt_state_host())))
                    else:
                        conn.send(("params", step,
                                   serde.encode_tree(host)))
                except (OSError, BrokenPipeError):
                    pass

        metrics, tel = learner.run(
            spec["steps"], warm_buckets=spec.get("warm_buckets", False),
            on_update=(on_update
                       if (tel_every or tel_interval or ckpt_every)
                       else None),
            should_stop=stop_event.is_set)

        import zlib
        params_buf = serde.encode_tree(learner.published_host())
        result = {
            "learner_id": learner_id,
            "returns": learner.tracker.completed_timed,
            "metrics": {k: float(np.asarray(v))
                        for k, v in metrics.items()},
            "telemetry": tel,
            "param_version": learner.store.version,
            # every worker digests its final replica: the parent can
            # verify the group's data-parallel invariant (identical
            # replicas) without shipping N full parameter trees
            "params_digest": zlib.crc32(params_buf),
        }
        if state["publisher"]:
            # the designated publisher ships its final params so the
            # parent can checkpoint / compare without touching jax
            result["params"] = params_buf
        conn.send(("result", result))
        status = 0
    except BaseException:
        try:
            conn.send(("error", learner_id, traceback.format_exc()))
        except (OSError, BrokenPipeError):
            pass
        try:
            stop_event.set()            # unwedge the peers' exchanges
        except Exception:
            pass
    finally:
        try:
            conn.close()
        except OSError:
            pass
    os._exit(status)


# ---------------------------------------------------------------------------
# the group runner


def run_group_training(
    env_name: str,
    icfg,
    num_envs: int,
    steps: int,
    *,
    num_learners: int = 2,
    num_actors: int = 2,
    actor_backend: str = "thread",
    actor_mode: str = "unroll",
    transport: Optional[str] = None,
    listen_addr: Optional[Address] = None,
    spawn_remote: bool = True,
    queue_capacity: int = 8,
    queue_policy: str = "block",
    max_batch_trajs: int = 4,
    batch_linger_s: float = 0.0,
    seed: int = 0,
    arch=None,
    donate: bool = True,
    start_step: int = 0,
    warm_buckets: bool = False,
    stale_after_s: float = 180.0,
    infer_flush_timeout_s: float = 0.02,
    infer_streams: int = 1,
    wire_codec: str = serde.DEFAULT_CODEC,
    vtrace_impl: str = "auto",
    telemetry_every: int = 0,
    telemetry_interval_s: float = 0.0,
    on_progress=None,
    ckpt_every: int = 0,
    on_checkpoint=None,
    return_final_params: bool = False,
    join_timeout_s: float = 60.0,
    obs=None,
    supervise: bool = False,
    restart_policy: Optional[RestartPolicy] = None,
    failover_deadline_s: float = 20.0,
    ckpt_dir: Optional[str] = None,
    resume_from: Optional[str] = None,
):
    """Train ``steps`` synchronized rounds across ``num_learners``
    learner worker processes, the run's ``num_actors`` actor slots
    sharded contiguously over them.

    Every round, each learner backward-passes one dynamic batch from
    its own transport, the gradients are mean-reduced over the framed
    channel, and every learner applies the same mean — so after round
    t all replicas hold identical parameters published at version
    ``start_step + t + 1`` by delegation from the hub (learner 0, the
    designated publisher). ``stale_after_s`` is the drop rule: a
    learner that misses the round deadline is excluded from that
    round's mean (counted in ``group.stale_dropped``) but still
    receives and applies it.

    ``num_learners=1`` runs the same worker machinery with no exchange
    — the worker is then *exactly* ``run_async_training`` (same fused
    donated train step, same seeding), which the first-train-step
    bit-match test pins.

    ``telemetry_every``/``on_progress`` stream per-learner snapshots to
    the caller mid-run (the CLI's live log lines);
    ``telemetry_interval_s`` adds *time-based* snapshot shipping on top
    (each worker also sends whenever that much wall time passed since
    its last send). ``ckpt_every``/``on_checkpoint`` stream the
    publisher's replica (host numpy tree — replicas are identical, one
    copy suffices) every that-many updates, the mid-run checkpoint
    hook.

    ``obs`` (an ``repro.obs.ObsConfig``) with ``metrics_port`` set runs
    the group hub's metrics endpoint in THIS process: the workers ship
    their registries' snapshots up the existing pipes periodically
    (``telemetry_interval_s``, defaulting to
    ``obs.telemetry_interval_s``) and ``/metrics`` serves the
    ``merge_telemetry`` of the latest per-learner snapshots — one port
    exposes queue depth, fps, lag histograms, reconnects, torn tails
    for the whole fleet, each learner's subtree labelled
    ``learner="k"``. The bound address lands in ``obs.bound_address``.

    ``supervise=True`` turns faults into events: a spoke learner worker
    that dies silently (SIGKILL, OOM) is respawned from the latest
    group checkpoint (or from scratch, riding the hub's mean-replay
    history) under ``restart_policy``'s budget; a dead *hub* triggers
    failover — the lowest live learner id is promoted, survivors redial
    it, and the round/version stream continues uninterrupted. A
    survivor that cannot rejoin within ``failover_deadline_s`` degrades
    to solo training with a loud ``degraded_solo`` flag. All of it is
    counted in the merged telemetry's ``supervisor`` section.

    ``ckpt_dir`` + ``ckpt_every`` save periodic *group* checkpoints
    (publisher params + optimizer state + version + restart epochs);
    ``resume_from`` starts every worker from the latest such checkpoint,
    continuing the same monotonic version stream.

    Returns ``(tracker, last_metrics, merged_telemetry)`` — shaped like
    ``run_async_training``'s triple, with the telemetry merged by
    ``merge_telemetry`` (per-learner snapshots under ``learners.*``) —
    or a 4-tuple with the publisher's final params (host numpy tree)
    appended when ``return_final_params=True``.
    """
    if not isinstance(env_name, str):
        raise ValueError("learner-group workers rebuild the env by "
                         "name; pass an env name, not an Env object")
    if transport is None:
        transport = {"process": "shm",
                     "remote": "socket"}.get(actor_backend, "inproc")
    shards = shard_slots(num_actors, num_learners)
    listen_addrs = None
    peer_addrs = None
    if transport == "socket":
        if listen_addr is not None:
            host, port = listen_addr
            listen_addrs = [(host, port + k) for k in range(num_learners)]
            peer_addrs = list(listen_addrs)
        elif not spawn_remote:
            raise ValueError("a learner group waiting for external "
                             "actors needs an explicit listen_addr "
                             "(worker k binds port+k)")

    resume_spec = None
    if resume_from is not None:
        from repro.checkpoint import checkpoint as ckpt_lib
        tree, ck_step, extra = ckpt_lib.load_with_extra(resume_from)
        if not (isinstance(tree, dict) and "params" in tree
                and "opt" in tree):
            raise ValueError(
                f"group resume needs a combined params+opt checkpoint "
                f"(fleet-v1); {resume_from} holds a params-only tree")
        version = int((extra or {}).get("version", ck_step))
        resume_spec = {"params": serde.encode_tree(tree["params"]),
                       "opt": serde.encode_tree(tree["opt"]),
                       "version": version}
        start_step = version

    spec = {
        "env": env_name, "icfg": icfg, "num_envs": num_envs,
        "steps": steps, "num_learners": num_learners,
        "shards": shards, "actor_backend": actor_backend,
        "actor_mode": actor_mode, "transport": transport,
        "listen_addrs": listen_addrs, "peer_addrs": peer_addrs,
        "spawn_remote": spawn_remote,
        "queue_capacity": queue_capacity, "queue_policy": queue_policy,
        "max_batch_trajs": max_batch_trajs,
        "batch_linger_s": batch_linger_s, "seed": seed, "arch": arch,
        "donate": donate, "start_step": start_step,
        "warm_buckets": warm_buckets, "stale_after_s": stale_after_s,
        "infer_flush_timeout_s": infer_flush_timeout_s,
        "infer_streams": infer_streams,
        "wire_codec": serde.check_codec(wire_codec),
        "vtrace_impl": vtrace_impl,
        "telemetry_every": telemetry_every, "publisher": 0,
        "hub_id": 0, "supervise": supervise,
        "failover_deadline_s": failover_deadline_s,
        "resume": resume_spec,
        # full checkpoints (params + opt state) whenever the parent
        # needs restartable state: a ckpt_dir to save into, or a
        # supervised run (respawns start from the latest one)
        "ckpt_full": supervise or ckpt_dir is not None,
        "telemetry_interval_s": (
            telemetry_interval_s or
            (obs.telemetry_interval_s
             if obs is not None and obs.metrics_port is not None
             else 0.0)),
        "ckpt_every": (ckpt_every
                       if (on_checkpoint is not None or supervise or
                           ckpt_dir is not None) else 0),
    }

    ctx = mp.get_context("spawn")
    # kill-safe: chaos tests (and real preemption) SIGKILL learner
    # workers; a corpse holding mp.Event's lock would deadlock the
    # parent's own stop.set() at teardown
    stop = KillSafeEvent(ctx)
    conns: List[Any] = []
    procs: List[mp.process.BaseProcess] = []
    for k in range(num_learners):
        parent_conn, child_conn = ctx.Pipe()
        # NOT daemonic: a learner worker spawns actor children of its
        # own (process/remote backends), which daemons may not. The
        # finally block below joins with a deadline and terminates
        # stragglers, so no worker outlives the run.
        p = ctx.Process(target=_learner_worker,
                        args=(k, child_conn, stop, spec),
                        name=f"learner-{k}")
        conns.append(parent_conn)
        procs.append(p)
        p.start()
        child_conn.close()
    all_procs: List[mp.process.BaseProcess] = list(procs)

    results: Dict[int, Dict] = {}
    errors: List[str] = []
    latest_tel: Dict[int, Dict] = {}
    hub_sent = False
    live = set(range(num_learners))

    # supervision state (parent side)
    sup = Supervisor(restart_policy) if supervise else None
    current_hub = 0                     # publisher duty follows it
    hub_addr: Optional[List] = None
    failover_pending = False
    pending_respawn: Dict[int, Any] = {}    # k -> RestartDecision
    abandoned: set = set()              # hub ids lost to failover
    latest_ckpt: Optional[Dict[str, Any]] = None

    server = None
    if obs is not None and obs.metrics_port is not None:
        from repro.obs.http import MetricsServer

        def group_snapshot() -> Dict[str, Any]:
            tels = dict(latest_tel)
            if not tels:        # nothing shipped yet: a stub, not a 500
                snap = {"group": {"num_learners": num_learners,
                                  "publisher": current_hub,
                                  "stale_dropped": 0,
                                  "awaiting_first_telemetry": True}}
            else:
                snap = merge_telemetry(tels, publisher=current_hub)
            if sup is not None:
                snap["supervisor"] = sup.snapshot()
            return snap

        server = MetricsServer(group_snapshot, host=obs.metrics_host,
                               port=obs.metrics_port).start()
        obs.bound_address = server.address
        print(f"[obs] group metrics at http://{server.address[0]}:"
              f"{server.address[1]}/metrics", flush=True)

    def _relay_hub(addr, exclude=frozenset((0,))) -> None:
        for j in range(num_learners):
            if j in exclude:
                continue
            try:
                conns[j].send(("hub", addr))
            except (OSError, BrokenPipeError):
                pass

    def _save_group_ckpt(step: int) -> None:
        if ckpt_dir is None or latest_ckpt is None:
            return
        from repro.checkpoint import checkpoint as ckpt_lib
        tree = {"params": serde.decode_tree(latest_ckpt["params"],
                                            copy=True)[0],
                "opt": serde.decode_tree(latest_ckpt["opt"],
                                         copy=True)[0]}
        extra = {"version": latest_ckpt["version"],
                 "format": "fleet-v1",
                 "restart_epochs": (sup.restart_epochs()
                                    if sup is not None else {})}
        ckpt_lib.save(ckpt_dir, step, tree, extra=extra)

    def _fail(msg: str) -> None:
        nonlocal hub_sent
        errors.append(msg)
        stop.set()
        if not hub_sent:
            hub_sent = True
            _relay_hub(None)

    def _handle_death(k: int) -> None:
        """A worker died silently (no error message: SIGKILL / OOM).
        Supervised, that is an event — failover for the hub, respawn
        for a spoke — not a run-ending error."""
        nonlocal current_hub, failover_pending
        if sup is None:
            _fail(f"learner worker {k} exited with code "
                  f"{procs[k].exitcode} before reporting")
            return
        if k == current_hub:
            survivors = sorted(live)
            if not survivors:
                _fail(f"hub learner {k} died with no survivors "
                      f"to promote")
                return
            # hub failover: promote the lowest live learner id; its
            # actor shard is lost (graceful degradation), the round
            # and version stream continue on the new hub
            abandoned.add(k)
            sup.record_failover()
            current_hub = survivors[0]
            failover_pending = True
            for j in survivors:
                try:
                    conns[j].send(("failover", current_hub, k))
                except (OSError, BrokenPipeError):
                    pass
        else:
            decision = sup.record_death(f"learner-{k}")
            if decision is None:
                _fail(f"learner worker {k} died over its restart "
                      f"budget ({sup.policy.max_restarts} per "
                      f"{sup.policy.window_s:.0f}s)")
                return
            pending_respawn[k] = decision

    def _maybe_respawn() -> None:
        now = time.monotonic()
        for k in [k for k, d in pending_respawn.items()
                  if d.not_before <= now]:
            d = pending_respawn.pop(k)
            respec = dict(spec)
            respec["hub_id"] = current_hub
            if latest_ckpt is not None:
                # restart from the latest group checkpoint; the hub's
                # mean-replay history carries it from that version to
                # the group's current round
                respec["resume"] = dict(latest_ckpt)
                # fresh RNG streams for the reborn actors — but only
                # when params come from a checkpoint; from-scratch
                # respawns must re-derive the identical replica
                # (same init, same mean sequence), so the seed stays
                respec["seed"] = fold_restart_seed(seed, d.epoch)
            parent_conn, child_conn = ctx.Pipe()
            p = ctx.Process(target=_learner_worker,
                            args=(k, child_conn, stop, respec),
                            name=f"learner-{k}-r{d.epoch}")
            conns[k] = parent_conn
            procs[k] = p
            all_procs.append(p)
            p.start()
            child_conn.close()
            live.add(k)
            sup.note_restarted(f"learner-{k}")
            # mid-failover the only known address is the dead hub's;
            # the reborn spoke then waits for the relayed new one
            if hub_addr is not None and not failover_pending:
                try:
                    parent_conn.send(("hub", hub_addr))
                except (OSError, BrokenPipeError):
                    pass

    def _on_worker_gone(k: int) -> None:
        live.discard(k)
        if k in results or errors:
            return
        _handle_death(k)

    try:
        while live or pending_respawn:
            _maybe_respawn()
            ready = mp_connection.wait([conns[k] for k in live],
                                       timeout=0.2 if pending_respawn
                                       else 0.5)
            if not ready:
                for k in list(live):
                    if procs[k].exitcode is not None:
                        _on_worker_gone(k)
                continue
            for conn in ready:
                k = conns.index(conn)
                try:
                    msg = conn.recv()
                except (EOFError, OSError):
                    if k not in results and sup is None and not errors:
                        _fail(f"learner worker {k} died without "
                              f"reporting (pipe EOF)")
                        live.discard(k)
                    else:
                        _on_worker_gone(k)
                    continue
                tag = msg[0]
                if tag == "hub":
                    hub_sent = True
                    hub_addr = msg[1]
                    _relay_hub(msg[1], exclude={k})
                    if failover_pending:
                        failover_pending = False
                        sup.note_failover_done()
                elif tag == "telemetry":
                    # every telemetry_every updates each worker ships a
                    # snapshot; on_progress(learner_id, snap) is the
                    # live-logging hook (the CLI prints from it)
                    latest_tel[k] = msg[1]
                    if on_progress is not None:
                        on_progress(k, msg[1])
                elif tag == "params":
                    # periodic publisher checkpoint: (step, host tree)
                    if on_checkpoint is not None:
                        on_checkpoint(
                            msg[1],
                            serde.decode_tree(msg[2], copy=True)[0])
                elif tag == "ckpt":
                    # full group checkpoint stream: (step, version,
                    # params, opt state) — respawn source + disk save
                    latest_ckpt = {"params": msg[3], "opt": msg[4],
                                   "version": int(msg[2])}
                    if on_checkpoint is not None:
                        on_checkpoint(
                            msg[1],
                            serde.decode_tree(msg[3], copy=True)[0])
                    _save_group_ckpt(int(msg[1]))
                elif tag == "error":
                    _fail(f"learner worker {msg[1]}:\n{msg[2]}")
                    live.discard(k)
                elif tag == "result":
                    results[k] = msg[1]
                    live.discard(k)
    finally:
        if server is not None:
            server.stop()
        if errors:
            stop.set()
        deadline = time.monotonic() + join_timeout_s
        for p in all_procs:
            p.join(max(0.1, deadline - time.monotonic()))
        for p in all_procs:
            if p.is_alive():                # no orphans, ever
                p.terminate()
                p.join(timeout=5.0)
        for conn in conns:
            try:
                conn.close()
            except OSError:
                pass

    if errors:
        raise RuntimeError("learner group failed:\n" + errors[0])
    expected = set(range(num_learners)) - abandoned
    if not expected <= set(results):
        missing = sorted(expected - set(results))
        raise RuntimeError(f"learner worker(s) {missing} produced no "
                           f"result")

    tracker = GroupTracker([tuple(p) for r in results.values()
                            for p in r["returns"]])
    versions = sorted(r["param_version"] for r in results.values())
    digests = {f"learner_{k}": r["params_digest"]
               for k, r in sorted(results.items())}
    group_extra = {"rounds": steps,
                   "wire_codec": wire_codec,
                   "param_versions": versions,
                   "param_digests": digests,
                   "replicas_identical": len(set(digests.values())) == 1,
                   "transport": transport}
    if abandoned:
        group_extra["abandoned_learners"] = sorted(abandoned)
    telemetry = merge_telemetry(
        {k: r["telemetry"] for k, r in results.items()},
        publisher=current_hub,
        group_extra=group_extra)
    if sup is not None:
        telemetry["supervisor"] = sup.snapshot()
    metrics = results[current_hub]["metrics"]
    if return_final_params:
        params, _meta = serde.decode_tree(results[current_hub]["params"],
                                          copy=True)
        return tracker, metrics, telemetry, params
    return tracker, metrics, telemetry
