"""Serialization boundary for the trajectory pipeline.

A ``TrajectoryItem`` (trajectory pytree + provenance) is flattened into a
single spec-described contiguous byte buffer and restored *exactly* —
same nesting, same key order, same dtypes (including bfloat16), same
bits. This is the boundary that lets trajectories cross a real wire
(pipe, shared memory, later a socket) instead of being live jax pytrees
shared between threads of one interpreter.

Wire format (little-endian throughout)::

    [4B magic 'RTJ1'][4B uint32 header length][header JSON utf-8][payload]

The header is a JSON *spec*: a recursive structure descriptor whose leaf
nodes carry ``(dtype, shape, byte offset, byte length)`` into the payload,
plus the item's provenance (param version, actor id, produced_at). The
payload is the leaves' raw bytes, concatenated in spec order. Decoding is
zero-copy: each leaf is a (read-only) numpy view into the received buffer.

Deliberately no jax import: actors and transports must be able to move
buffers (and tests must be able to spawn producer processes) without
paying a jax import. ``np.asarray`` converts incoming jax arrays on
encode; bfloat16 comes from ``ml_dtypes``, which numpy interops with.

Supported pytree nodes: dict (string keys, insertion order preserved),
list, tuple, None, and array-like leaves (numpy/jax arrays and python
scalars). Namedtuples are encoded structurally as tuples.

Wire codecs (the bandwidth diet): ``encode_tree`` and friends accept a
``codec`` — ``"none"`` is today's raw little-endian wire, bit-exact.
``"bf16"`` ships float32/float64 leaves as bfloat16 (lossy, ~3
significant digits); ``"int8"`` ships them as int8 with a per-leaf
absmax scale (lossy, max abs error <= absmax/127). Under either lossy
codec, every *non-quantized* leaf additionally rides deflate-compressed
when that is smaller (lossless — this is what crushes the sparse uint8
observation planes and the near-constant discount rows). The spec stays
per-leaf self-describing: a leaf node carries its *logical* dtype plus
an ``enc`` tag (``bf16``/``q8``/``z``) and, for ``q8``, the scale — so
decode always restores the logical dtype and shape, whatever codec the
encoder picked. ``bf16`` is an exact fixed point (re-encoding a decoded
tree reproduces the same bytes); ``int8`` loses at most absmax/127 per
element on the first pass and is stable to float rounding after.
"""
from __future__ import annotations

import dataclasses
import json
import struct
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import ml_dtypes
import numpy as np

PyTree = Any

MAGIC = b"RTJ1"
_HDR = struct.Struct("<4sI")

# dtype registry: everything a trajectory / parameter pytree may carry.
_DTYPES: Dict[str, np.dtype] = {
    np.dtype(t).name: np.dtype(t)
    for t in (np.float64, np.float32, np.float16, np.int64, np.int32,
              np.int16, np.int8, np.uint64, np.uint32, np.uint16, np.uint8,
              np.bool_, np.complex64, np.complex128)
}
_DTYPES["bfloat16"] = np.dtype(ml_dtypes.bfloat16)


@dataclasses.dataclass
class TrajectoryItem:
    """What flows through a transport: the trajectory pytree plus the
    provenance needed for measured lag and per-actor accounting.

    ``trace`` is the flight recorder's sampled-lifecycle stamp dict
    (CLOCK_MONOTONIC seconds; see ``repro.obs.trace``) — None on the
    unsampled fast path, and optional in the wire meta so old encoders
    and new decoders interoperate both ways."""
    data: PyTree
    param_version: int
    actor_id: int
    produced_at: float
    trace: Optional[Dict[str, float]] = None


class SerdeError(ValueError):
    pass


class CodecMismatchError(SerdeError):
    """A peer announced (or a caller requested) a wire codec this side
    does not support. Distinct from plain ``SerdeError`` so a handshake
    can refuse loudly instead of feeding garbage to a decoder."""


# wire codec registry: "none" is the raw bit-exact wire; the lossy
# codecs quantize float32/float64 leaves and deflate the rest
WIRE_CODECS = ("none", "bf16", "int8")
DEFAULT_CODEC = "none"

# deflate: cheapest level — the compressible leaves (sparse observation
# planes, constant discount rows) crush at any level, and the actor-side
# encode sits on the trajectory hot path
_Z_LEVEL = 1
# leaves smaller than this aren't worth the per-leaf deflate header
_Z_MIN_BYTES = 64


def check_codec(codec: str) -> str:
    """Validate a codec name; raises ``CodecMismatchError`` on anything
    not in ``WIRE_CODECS`` (the loud path for handshake negotiation)."""
    if codec not in WIRE_CODECS:
        raise CodecMismatchError(
            f"unsupported wire codec {codec!r} "
            f"(this side speaks {', '.join(WIRE_CODECS)})")
    return codec


# ---------------------------------------------------------------------------
# spec construction / encoding


def _encode_leaf(arr: np.ndarray, path: str, codec: str,
                 select) -> Tuple[bytes, Dict[str, Any]]:
    """One leaf's payload bytes + the spec fields beyond dtype/shape.

    ``codec != "none"``: float32/float64 leaves passing ``select`` are
    quantized (``enc``: ``bf16`` or ``q8`` + per-leaf ``scale``); every
    other leaf is deflated when that wins (``enc``: ``z``). The logical
    dtype always stays in the spec — decode restores it."""
    raw = arr.tobytes()                      # contiguous little-endian copy
    if codec == "none":
        return raw, {}
    quantizable = (arr.dtype.kind == "f" and arr.itemsize >= 4 and
                   arr.size > 0 and (select is None or select(path, arr)))
    if quantizable:
        if codec == "bf16":
            return arr.astype(ml_dtypes.bfloat16).tobytes(), {"enc": "bf16"}
        if codec == "int8":
            absmax = float(np.max(np.abs(arr)))
            if np.isfinite(absmax):
                scale = absmax / 127.0
                if scale == 0.0:
                    q = np.zeros(arr.shape, np.int8)
                else:
                    q = np.clip(np.rint(arr / scale), -127,
                                127).astype(np.int8)
                return q.tobytes(), {"enc": "q8", "scale": scale}
            # non-finite leaves (inf/nan) have no absmax scale: ship raw
        else:
            raise CodecMismatchError(f"unsupported wire codec {codec!r}")
    if len(raw) >= _Z_MIN_BYTES:
        z = zlib.compress(raw, _Z_LEVEL)
        if len(z) < len(raw):
            return z, {"enc": "z"}
    return raw, {}


def _encode_node(tree: PyTree, chunks: List[bytes], offset: int,
                 path: str, codec: str = DEFAULT_CODEC,
                 select=None) -> Tuple[Dict[str, Any], int]:
    """Append ``tree``'s leaves to ``chunks`` (starting at byte ``offset``)
    and return (spec node, next offset)."""
    if tree is None:
        return {"t": "none"}, offset
    if isinstance(tree, dict):
        keys, children = [], []
        for k in tree:                      # insertion order IS the spec
            if not isinstance(k, str):
                raise SerdeError(f"non-string dict key {k!r} at {path}")
            node, offset = _encode_node(tree[k], chunks, offset,
                                        f"{path}/{k}", codec, select)
            keys.append(k)
            children.append(node)
        return {"t": "dict", "keys": keys, "children": children}, offset
    if isinstance(tree, (list, tuple)):
        kind = "tuple" if isinstance(tree, tuple) else "list"
        children = []
        for i, child in enumerate(tree):
            node, offset = _encode_node(child, chunks, offset,
                                        f"{path}[{i}]", codec, select)
            children.append(node)
        return {"t": kind, "children": children}, offset
    # leaf: anything numpy can view (jax arrays and python scalars too).
    # tobytes() yields a C-order copy whatever the input strides, and —
    # unlike ascontiguousarray — keeps 0-d shapes 0-d.
    arr = np.asarray(tree)
    name = arr.dtype.name
    if name not in _DTYPES:
        raise SerdeError(f"unsupported leaf dtype {name!r} at {path}")
    stored, extra = _encode_leaf(arr, path, codec, select)
    chunks.append(stored)
    node = {"t": "a", "dtype": name, "shape": list(arr.shape),
            "off": offset, "n": len(stored)}
    node.update(extra)
    return node, offset + len(stored)


def tree_spec(tree: PyTree, codec: str = DEFAULT_CODEC) -> Dict[str, Any]:
    """The structure descriptor alone (offsets included) — what the header
    carries. Useful for tests and for reasoning about compatibility."""
    spec, _ = _encode_node(tree, [], 0, "$", codec)
    return spec


def tree_nbytes(tree: PyTree) -> int:
    """Raw (uncompressed) leaf bytes of ``tree`` — the denominator for
    wire-compression accounting."""
    if tree is None:
        return 0
    if isinstance(tree, dict):
        return sum(tree_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(tree_nbytes(v) for v in tree)
    nbytes = getattr(tree, "nbytes", None)   # numpy AND jax arrays —
    if nbytes is not None:                   # no device->host copy
        return int(nbytes)
    return np.asarray(tree).nbytes


def encode_tree(tree: PyTree, meta: Optional[Dict[str, Any]] = None,
                codec: str = DEFAULT_CODEC, select=None) -> bytes:
    """Flatten ``tree`` into one contiguous buffer. ``meta`` must be
    JSON-serializable; it rides in the header (provenance, version, ...).
    ``codec``/``select`` pick the wire codec (module docstring)."""
    chunks: List[bytes] = []
    spec, total = _encode_node(tree, chunks, 0, "$", codec, select)
    header = json.dumps({"meta": meta or {}, "tree": spec},
                        separators=(",", ":")).encode("utf-8")
    return b"".join([_HDR.pack(MAGIC, len(header)), header] + chunks)


# ---------------------------------------------------------------------------
# decoding


def _decode_node(node: Dict[str, Any], payload: memoryview,
                 copy: bool) -> PyTree:
    t = node["t"]
    if t == "none":
        return None
    if t == "dict":
        return {k: _decode_node(c, payload, copy)
                for k, c in zip(node["keys"], node["children"])}
    if t == "list":
        return [_decode_node(c, payload, copy) for c in node["children"]]
    if t == "tuple":
        return tuple(_decode_node(c, payload, copy)
                     for c in node["children"])
    if t == "a":
        dtype = _DTYPES.get(node["dtype"])
        if dtype is None:
            raise SerdeError(f"unknown dtype in spec: {node['dtype']!r}")
        off, n = node["off"], node["n"]
        enc = node.get("enc")
        stored = payload[off:off + n]
        if enc is None:
            arr = np.frombuffer(stored, dtype=dtype)
            arr = arr.reshape(node["shape"])
            return arr.copy() if copy else arr
        # encoded leaves always allocate (the dequantized/ inflated
        # array cannot be a view of the wire buffer)
        return _decode_encoded_leaf(node, stored, dtype)
    raise SerdeError(f"unknown spec node type {t!r}")


def _decode_encoded_leaf(node: Dict[str, Any], stored: memoryview,
                         dtype: np.dtype) -> np.ndarray:
    """Restore one quantized/deflated leaf to its logical dtype/shape."""
    enc, shape = node["enc"], node["shape"]
    try:
        if enc == "bf16":
            src = np.frombuffer(stored, dtype=np.dtype(ml_dtypes.bfloat16))
            return src.reshape(shape).astype(dtype)
        if enc == "q8":
            src = np.frombuffer(stored, dtype=np.int8).reshape(shape)
            out = src.astype(dtype)
            np.multiply(out, dtype.type(node["scale"]), out=out)
            return out
        if enc == "z":
            raw = zlib.decompress(bytes(stored))
            return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()
    except (zlib.error, ValueError) as e:
        raise SerdeError(f"corrupt {enc!r}-encoded leaf: {e}") from e
    raise SerdeError(f"unknown leaf encoding {enc!r}")


def decode_tree(buf: bytes, copy: bool = False
                ) -> Tuple[PyTree, Dict[str, Any]]:
    """Inverse of ``encode_tree``: returns (tree, meta).

    ``copy=False`` (default) decodes leaves as zero-copy read-only views
    of ``buf``; pass ``copy=True`` when the caller needs writable arrays
    or must outlive the buffer.
    """
    if len(buf) < _HDR.size:
        raise SerdeError(f"buffer too short ({len(buf)} bytes)")
    magic, hlen = _HDR.unpack_from(buf)
    if magic != MAGIC:
        raise SerdeError(f"bad magic {magic!r} (expected {MAGIC!r})")
    start = _HDR.size
    header = json.loads(bytes(buf[start:start + hlen]).decode("utf-8"))
    payload = memoryview(buf)[start + hlen:]
    tree = _decode_node(header["tree"], payload, copy)
    return tree, header.get("meta", {})


def _fill_node(node: Dict[str, Any], payload: memoryview, dst: PyTree,
               path: str) -> None:
    t = node["t"]
    if t == "none":
        if dst is not None:
            raise SerdeError(f"structure mismatch at {path}: buffer has "
                             f"None, destination has {type(dst).__name__}")
        return
    if t == "dict":
        if not isinstance(dst, dict) or list(dst) != node["keys"]:
            raise SerdeError(f"structure mismatch at {path}: dict keys "
                             f"differ")
        for k, c in zip(node["keys"], node["children"]):
            _fill_node(c, payload, dst[k], f"{path}/{k}")
        return
    if t in ("list", "tuple"):
        if not isinstance(dst, (list, tuple)) or \
                len(dst) != len(node["children"]):
            raise SerdeError(f"structure mismatch at {path}: sequence "
                             f"arity differs")
        for i, c in enumerate(node["children"]):
            _fill_node(c, payload, dst[i], f"{path}[{i}]")
        return
    if t == "a":
        dtype = _DTYPES.get(node["dtype"])
        if dtype is None:
            raise SerdeError(f"unknown dtype in spec: {node['dtype']!r}")
        if not isinstance(dst, np.ndarray) or dst.dtype != dtype or \
                list(dst.shape) != node["shape"]:
            raise SerdeError(f"leaf mismatch at {path}: buffer is "
                             f"{node['dtype']}{node['shape']}, destination "
                             f"is {getattr(dst, 'dtype', None)}"
                             f"{list(getattr(dst, 'shape', ()))}")
        off, n = node["off"], node["n"]
        if node.get("enc") is None:
            src = np.frombuffer(payload[off:off + n],
                                dtype=dtype).reshape(node["shape"])
            np.copyto(dst, src)
        else:
            # dequantize/inflate straight into the preallocated leaf
            np.copyto(dst, _decode_encoded_leaf(node, payload[off:off + n],
                                                dtype))
        return
    raise SerdeError(f"unknown spec node type {t!r}")


def decode_tree_into(buf: bytes, dst: PyTree) -> Dict[str, Any]:
    """Decode ``buf`` *into* an existing tree of writable numpy leaves.

    The steady-state receive path for repeated same-shaped payloads
    (e.g. a parameter subscriber decoding every published version):
    instead of allocating a fresh tree per message (``decode_tree(buf,
    copy=True)``), the payload bytes are copied straight into ``dst``'s
    preallocated leaves. Structure, dtypes, and shapes must match the
    buffer's spec exactly — a mismatch raises ``SerdeError`` with the
    offending path, and the caller falls back to a fresh decode.
    Returns the header meta."""
    if len(buf) < _HDR.size:
        raise SerdeError(f"buffer too short ({len(buf)} bytes)")
    magic, hlen = _HDR.unpack_from(buf)
    if magic != MAGIC:
        raise SerdeError(f"bad magic {magic!r} (expected {MAGIC!r})")
    start = _HDR.size
    header = json.loads(bytes(buf[start:start + hlen]).decode("utf-8"))
    payload = memoryview(buf)[start + hlen:]
    _fill_node(header["tree"], payload, dst, "$")
    return header.get("meta", {})


# ---------------------------------------------------------------------------
# TrajectoryItem convenience layer


# trajectory leaves a lossy codec may quantize: the observation side
# (image/token inputs and the recurrent state the unroll starts from).
# The V-trace-critical scalars (rewards, discounts, behaviour_logprob)
# stay bit-exact — quantizing the behaviour policy's own log-probs
# would corrupt the importance weights the correction is built on.
_TRAJ_QUANT_KEYS = ("obs_image", "obs_token", "lstm_state")


def _traj_select(path: str, arr: np.ndarray) -> bool:
    return any(f"/{k}" in path for k in _TRAJ_QUANT_KEYS)


def encode_item(item: TrajectoryItem, codec: str = DEFAULT_CODEC) -> bytes:
    meta = {
        "param_version": int(item.param_version),
        "actor_id": int(item.actor_id),
        "produced_at": float(item.produced_at),
    }
    if item.trace is None:
        return encode_tree(item.data, meta=meta, codec=codec,
                           select=_traj_select)
    # flight-recorder path: build the payload bytes first, then stamp the
    # encode-end time ("e1") — the stamp can still ride in the header that
    # closes over those bytes, so the receiver sees when encoding finished
    chunks: List[bytes] = []
    spec, _ = _encode_node(item.data, chunks, 0, "$", codec, _traj_select)
    trace = dict(item.trace)
    trace["e1"] = time.monotonic()
    meta["trace"] = trace
    header = json.dumps({"meta": meta, "tree": spec},
                        separators=(",", ":")).encode("utf-8")
    return b"".join([_HDR.pack(MAGIC, len(header)), header] + chunks)


def decode_item(buf: bytes, copy: bool = False) -> TrajectoryItem:
    data, meta = decode_tree(buf, copy=copy)
    trace = meta.get("trace")
    return TrajectoryItem(data, int(meta["param_version"]),
                          int(meta["actor_id"]),
                          float(meta["produced_at"]),
                          dict(trace) if trace else None)


# ---------------------------------------------------------------------------
# gradient exchange payloads (the learner group's KIND_GRAD /
# KIND_GRAD_MEAN frames): a flat list of numpy gradient leaves plus the
# round bookkeeping the hub's stale-drop rule needs. The tree structure
# is NOT shipped — every learner of a data-parallel group holds the
# same parameter treedef, so only the leaves (in flatten order) cross
# the wire, and a structure mismatch surfaces as the usual SerdeError
# at unflatten time.


def encode_grads(leaves: List[np.ndarray], *, round_idx: int,
                 learner_id: int, version: int = -1,
                 codec: str = DEFAULT_CODEC) -> bytes:
    """One gradient-exchange payload: ``leaves`` in tree-flatten order,
    stamped with the update round and sender. ``version`` rides on the
    hub's KIND_GRAD_MEAN broadcast (the delegated publish version for
    the round); spokes send -1."""
    return encode_tree(list(leaves), meta={
        "round": int(round_idx),
        "learner": int(learner_id),
        "version": int(version),
    }, codec=codec)


def decode_grads(buf: bytes, copy: bool = False
                 ) -> Tuple[List[np.ndarray], Dict[str, Any]]:
    """Inverse of ``encode_grads``: (leaves, meta) where meta carries
    ``round``/``learner``/``version``. Zero-copy views by default —
    the hub only reads them into its accumulation."""
    leaves, meta = decode_tree(buf, copy=copy)
    if not isinstance(leaves, list):
        raise SerdeError(f"gradient payload must decode to a list of "
                         f"leaves, got {type(leaves).__name__}")
    return leaves, meta


# ---------------------------------------------------------------------------
# wire framing (the socket transport's unit of transmission)
#
# ``encode_tree`` buffers are self-describing but carry no *boundary*: a
# TCP stream needs one. Each message travels as a frame::
#
#     [4B magic 'RFR1'][1B kind][4B uint32 stream id]
#     [4B uint32 payload length][4B crc32(payload)][payload]
#
# ``kind`` multiplexes message types over one connection (trajectory,
# parameter pull/push, inference request/reply, control); ``stream_id``
# is kind-specific routing (client id, parameter version, ...). The CRC
# covers the kind/stream/length fields AND the payload — a flipped bit
# in the routing fields would otherwise deliver a valid payload to the
# wrong client — and turns silent wire corruption and misframing into a
# loud ``SerdeError`` at the receiver; on a byte stream a single
# flipped or lost bit would otherwise desynchronise *every* later
# frame. A frame that ends early (peer killed mid-write) is detected by
# length, never delivered.


FRAME_MAGIC = b"RFR1"
_FRAME_HDR = struct.Struct("<4sBIII")      # magic, kind, stream, len, crc
_FRAME_META = struct.Struct("<BII")        # the crc-covered header part
FRAME_HEADER_SIZE = _FRAME_HDR.size
# sanity cap: no single message (trajectory, params, obs batch) comes
# near this; a corrupt length field must not provoke a giant allocation
MAX_FRAME_PAYLOAD = 1 << 30


def frame_crc(kind: int, stream_id: int, payload: bytes) -> int:
    """crc32 over (kind, stream_id, length, payload) — incremental, no
    payload copy."""
    meta = _FRAME_META.pack(kind, stream_id, len(payload))
    return zlib.crc32(payload, zlib.crc32(meta))


def pack_frame(kind: int, stream_id: int, payload: bytes = b"") -> bytes:
    """One wire frame: header (magic/kind/stream/length/crc) + payload."""
    if not 0 <= kind <= 0xFF:
        raise SerdeError(f"frame kind must fit a byte, got {kind}")
    if not 0 <= stream_id <= 0xFFFFFFFF:
        raise SerdeError(f"stream id must fit uint32, got {stream_id}")
    if len(payload) > MAX_FRAME_PAYLOAD:
        raise SerdeError(f"payload too large ({len(payload)} bytes)")
    return _FRAME_HDR.pack(FRAME_MAGIC, kind, stream_id, len(payload),
                           frame_crc(kind, stream_id, payload)) + payload


def parse_frame_header(hdr: bytes) -> Tuple[int, int, int, int]:
    """Validate a 17-byte frame header; returns (kind, stream_id,
    payload length, expected crc32). Raises ``SerdeError`` on bad magic
    or an implausible length — the caller must treat either as a
    desynchronised (torn) stream and drop the connection, because
    there is no way to re-find frame boundaries in a byte stream."""
    if len(hdr) != FRAME_HEADER_SIZE:
        raise SerdeError(f"frame header must be {FRAME_HEADER_SIZE} "
                         f"bytes, got {len(hdr)}")
    magic, kind, stream_id, length, crc = _FRAME_HDR.unpack(hdr)
    if magic != FRAME_MAGIC:
        raise SerdeError(f"bad frame magic {magic!r} "
                         f"(expected {FRAME_MAGIC!r})")
    if length > MAX_FRAME_PAYLOAD:
        raise SerdeError(f"implausible frame length {length}")
    return kind, stream_id, length, crc


def verify_frame_payload(kind: int, stream_id: int, payload: bytes,
                         crc: int) -> None:
    """CRC check over routing fields + payload; raises ``SerdeError``
    on mismatch (corrupt frame)."""
    actual = frame_crc(kind, stream_id, payload)
    if actual != crc:
        raise SerdeError(f"frame crc mismatch: header says {crc:#010x}, "
                         f"computed {actual:#010x}")


def unpack_frame(buf: bytes) -> Tuple[int, int, bytes, int]:
    """Decode one complete frame from the head of ``buf``; returns
    (kind, stream_id, payload, bytes consumed). Convenience for tests
    and in-memory use — the socket path reads header and payload
    separately off the stream."""
    kind, stream_id, length, crc = parse_frame_header(
        buf[:FRAME_HEADER_SIZE])
    end = FRAME_HEADER_SIZE + length
    if len(buf) < end:
        raise SerdeError(f"frame truncated: need {end} bytes, "
                         f"have {len(buf)}")
    payload = bytes(buf[FRAME_HEADER_SIZE:end])
    verify_frame_payload(kind, stream_id, payload, crc)
    return kind, stream_id, payload, end
