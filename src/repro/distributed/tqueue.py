"""Thread-safe bounded trajectory queue with selectable backpressure.

The paper's actors feed a learner-side queue (Fig. 1); what happens when
the learner falls behind is a real systems decision:

  block        producers wait for space — lossless, throttles actors to
               learner speed (TorchBeast's choice; right for equivalence
               runs and benchmarks that must count every frame).
  drop_oldest  evict the stalest queued trajectory — bounds both memory
               AND policy lag; the learner always trains on the freshest
               data (Ape-X-style priority for recency).
  drop_newest  reject the incoming trajectory — keeps FIFO order of what
               was already queued, wastes the newest actor work.

Every outcome is counted (pushed / popped / dropped / stalls) and
occupancy is accumulated at put-time so a telemetry snapshot can report
mean fill level without a sampler thread.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Deque, Dict, Optional

POLICIES = ("block", "drop_oldest", "drop_newest")


class TrajectoryQueue:
    """Bounded MPSC/MPMC queue for trajectory items (any Python object).

    ``on_drop`` (constructor arg or assignable attribute) is called with
    each item *evicted* by drop_oldest, so the producer that made it can
    be charged for the loss — drop_newest rejections are already visible
    to the caller via ``put`` returning False. The callback runs under
    the queue lock: it must be fast and must not re-enter the queue.
    """

    def __init__(self, capacity: int = 8, policy: str = "block",
                 on_drop: Optional[Callable[[Any], None]] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got "
                             f"{policy!r}")
        self.capacity = capacity
        self.policy = policy
        self.on_drop = on_drop
        # Transport contract (this class is registered as one): a put
        # returning False under drop_newest IS the rejection of that item
        self.rejects_at_put = True
        self._q: Deque[Any] = collections.deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._closed = False
        # counters (read under lock via snapshot())
        self.pushed = 0        # items accepted into the queue
        self.popped = 0        # items handed to consumers
        self.dropped = 0       # items lost (evicted or rejected)
        self.put_stalls = 0    # blocking puts that had to wait
        self.get_stalls = 0    # gets that had to wait
        self._occupancy_sum = 0
        self._occupancy_samples = 0

    # ------------------------------------------------------------------
    # producer side

    def put(self, item: Any, timeout: Optional[float] = None,
            count_stall: bool = True) -> bool:
        """Enqueue ``item`` under the configured backpressure policy.

        Returns True iff *this item* is now in the queue: False means the
        queue was closed, a blocking put timed out, or drop_newest
        rejected it. drop_oldest always accepts (evicting the stalest
        entry when full). Drops are counted *before* anything is removed,
        so the counter never lags the loss it reports. A producer
        retrying the same item after a timeout should pass
        ``count_stall=False`` so one stalled enqueue counts once, however
        many retries it takes.
        """
        with self._lock:
            if self._closed:
                return False
            if self.policy == "block":
                if len(self._q) >= self.capacity:
                    if count_stall:
                        self.put_stalls += 1
                    if not self._not_full.wait_for(
                            lambda: len(self._q) < self.capacity or
                            self._closed, timeout):
                        return False            # timed out, item not queued
                    if self._closed:
                        return False
                self._accept(item)
                return True
            if len(self._q) >= self.capacity:
                self.dropped += 1
                if self.policy == "drop_newest":
                    return False                # reject the incoming item
                evicted = self._q.popleft()     # drop_oldest: evict stalest
                if self.on_drop is not None:
                    self.on_drop(evicted)
            self._accept(item)
            return True

    def _accept(self, item: Any) -> None:
        self._q.append(item)
        self.pushed += 1
        self._occupancy_sum += len(self._q)
        self._occupancy_samples += 1
        self._not_empty.notify()

    # ------------------------------------------------------------------
    # consumer side

    def get(self, timeout: Optional[float] = None) -> Optional[Any]:
        """Dequeue the oldest item; None on timeout or closed-and-empty."""
        with self._lock:
            if not self._q:
                self.get_stalls += 1
                if not self._not_empty.wait_for(
                        lambda: self._q or self._closed, timeout):
                    return None
                if not self._q:
                    return None                 # closed and drained
            item = self._q.popleft()
            self.popped += 1
            self._not_full.notify()
            return item

    def get_nowait(self) -> Optional[Any]:
        with self._lock:
            if not self._q:
                return None
            item = self._q.popleft()
            self.popped += 1
            self._not_full.notify()
            return item

    def requeue_front(self, item: Any) -> None:
        """Put an already-popped item back at the head (learner-internal:
        dynamic batching took more than it could stack). Not counted as a
        new push; ignores capacity so nothing is lost."""
        with self._lock:
            self._q.appendleft(item)
            self.popped -= 1
            self._not_empty.notify()

    # ------------------------------------------------------------------

    def close(self) -> None:
        """Wake all blocked producers/consumers; subsequent puts fail and
        gets drain whatever is left."""
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            occ = (self._occupancy_sum / self._occupancy_samples
                   if self._occupancy_samples else 0.0)
            return {
                "capacity": self.capacity,
                "policy": self.policy,
                "size": len(self._q),
                "pushed": self.pushed,
                "popped": self.popped,
                "dropped": self.dropped,
                "put_stalls": self.put_stalls,
                "get_stalls": self.get_stalls,
                "mean_occupancy": occ,
            }
