"""Thread-safe bounded trajectory queue with selectable backpressure.

The paper's actors feed a learner-side queue (Fig. 1); what happens when
the learner falls behind is a real systems decision:

  block        producers wait for space — lossless, throttles actors to
               learner speed (TorchBeast's choice; right for equivalence
               runs and benchmarks that must count every frame).
  drop_oldest  evict the stalest queued trajectory — bounds both memory
               AND policy lag; the learner always trains on the freshest
               data (Ape-X-style priority for recency).
  drop_newest  reject the incoming trajectory — keeps FIFO order of what
               was already queued, wastes the newest actor work.

Every outcome is counted (pushed / popped / dropped / stalls) through
the metrics registry, and occupancy is integrated over time (depth ×
seconds at that depth) so a telemetry snapshot reports the true mean
fill level — including the time spent sitting at the current depth —
without a sampler thread.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, Deque, Dict, Optional

from repro.obs.metrics import Registry

POLICIES = ("block", "drop_oldest", "drop_newest")


class TrajectoryQueue:
    """Bounded MPSC/MPMC queue for trajectory items (any Python object).

    ``on_drop`` (constructor arg or assignable attribute) is called with
    each item *evicted* by drop_oldest, so the producer that made it can
    be charged for the loss — drop_newest rejections are already visible
    to the caller via ``put`` returning False. The callback runs under
    the queue lock: it must be fast and must not re-enter the queue.

    Counters live in a ``repro.obs.metrics.Registry`` (one is created
    when none is passed), written under the queue lock — the same
    serialization the raw ints had — and exposed as read-only properties
    so existing readers (``q.pushed`` etc.) are unchanged.
    """

    def __init__(self, capacity: int = 8, policy: str = "block",
                 on_drop: Optional[Callable[[Any], None]] = None,
                 registry: Optional[Registry] = None,
                 metrics_prefix: str = "queue"):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got "
                             f"{policy!r}")
        self.capacity = capacity
        self.policy = policy
        self.on_drop = on_drop
        # Transport contract (this class is registered as one): a put
        # returning False under drop_newest IS the rejection of that item
        self.rejects_at_put = True
        self._q: Deque[Any] = collections.deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._closed = False
        # counters (written under the lock; read any time — scalar reads
        # are atomic under the GIL)
        self.registry = registry if registry is not None else Registry()
        p = metrics_prefix
        self._c_pushed = self.registry.counter(f"{p}.pushed")
        self._c_popped = self.registry.counter(f"{p}.popped")
        self._c_dropped = self.registry.counter(f"{p}.dropped")
        self._c_put_stalls = self.registry.counter(f"{p}.put_stalls")
        self._c_get_stalls = self.registry.counter(f"{p}.get_stalls")
        self._g_size = self.registry.gauge(f"{p}.size")
        # time-weighted occupancy: the integral of depth over time.
        # _occ_area accumulates depth * seconds-at-that-depth, ticked
        # before every depth change; snapshot() folds in the open
        # interval at the current depth so the mean never goes stale
        # while the queue just sits there.
        self._occ_area = 0.0
        self._occ_last = time.monotonic()
        self._occ_t0 = self._occ_last

    # ------------------------------------------------------------------
    # counter views (the instruments are the storage)

    @property
    def pushed(self) -> int:
        return self._c_pushed.value

    @property
    def popped(self) -> int:
        return self._c_popped.value

    @property
    def dropped(self) -> int:
        return self._c_dropped.value

    @property
    def put_stalls(self) -> int:
        return self._c_put_stalls.value

    @property
    def get_stalls(self) -> int:
        return self._c_get_stalls.value

    def _occ_tick(self) -> None:
        """Integrate the time spent at the current depth. Call under the
        lock, immediately before any depth change."""
        now = time.monotonic()
        self._occ_area += len(self._q) * (now - self._occ_last)
        self._occ_last = now

    # ------------------------------------------------------------------
    # producer side

    def put(self, item: Any, timeout: Optional[float] = None,
            count_stall: bool = True) -> bool:
        """Enqueue ``item`` under the configured backpressure policy.

        Returns True iff *this item* is now in the queue: False means the
        queue was closed, a blocking put timed out, or drop_newest
        rejected it. drop_oldest always accepts (evicting the stalest
        entry when full). Drops are counted *before* anything is removed,
        so the counter never lags the loss it reports. A producer
        retrying the same item after a timeout should pass
        ``count_stall=False`` so one stalled enqueue counts once, however
        many retries it takes.
        """
        with self._lock:
            if self._closed:
                return False
            if self.policy == "block":
                if len(self._q) >= self.capacity:
                    if count_stall:
                        self._c_put_stalls.inc()
                    if not self._not_full.wait_for(
                            lambda: len(self._q) < self.capacity or
                            self._closed, timeout):
                        return False            # timed out, item not queued
                    if self._closed:
                        return False
                self._accept(item)
                return True
            if len(self._q) >= self.capacity:
                self._c_dropped.inc()
                if self.policy == "drop_newest":
                    return False                # reject the incoming item
                self._occ_tick()
                evicted = self._q.popleft()     # drop_oldest: evict stalest
                if self.on_drop is not None:
                    self.on_drop(evicted)
            self._accept(item)
            return True

    def _accept(self, item: Any) -> None:
        # flight-recorder receive stamp: one place covers every
        # transport, because inproc puts, the shm drain thread, and the
        # socket reader all land accepted items here. setdefault keeps
        # the earliest receipt if a retry loop re-puts the same item.
        tr = getattr(item, "trace", None)
        if tr is not None:
            tr.setdefault("r", time.monotonic())
        self._occ_tick()
        self._q.append(item)
        self._c_pushed.inc()
        self._g_size.set(len(self._q))
        self._not_empty.notify()

    # ------------------------------------------------------------------
    # consumer side

    def get(self, timeout: Optional[float] = None) -> Optional[Any]:
        """Dequeue the oldest item; None on timeout or closed-and-empty."""
        with self._lock:
            if not self._q:
                self._c_get_stalls.inc()
                if not self._not_empty.wait_for(
                        lambda: self._q or self._closed, timeout):
                    return None
                if not self._q:
                    return None                 # closed and drained
            self._occ_tick()
            item = self._q.popleft()
            self._c_popped.inc()
            self._g_size.set(len(self._q))
            self._not_full.notify()
            return item

    def get_nowait(self) -> Optional[Any]:
        with self._lock:
            if not self._q:
                return None
            self._occ_tick()
            item = self._q.popleft()
            self._c_popped.inc()
            self._g_size.set(len(self._q))
            self._not_full.notify()
            return item

    def requeue_front(self, item: Any) -> None:
        """Put an already-popped item back at the head (learner-internal:
        dynamic batching took more than it could stack). Not counted as a
        new push; ignores capacity so nothing is lost."""
        with self._lock:
            self._occ_tick()
            self._q.appendleft(item)
            self._c_popped.inc(-1)
            self._g_size.set(len(self._q))
            self._not_empty.notify()

    # ------------------------------------------------------------------

    def close(self) -> None:
        """Wake all blocked producers/consumers; subsequent puts fail and
        gets drain whatever is left."""
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            # fold in the open interval at the current depth so the mean
            # reflects "now", not just the last depth change (a queue
            # that filled to 2 and then idled must converge to 2, not
            # stay frozen at the put-time running mean)
            now = time.monotonic()
            area = self._occ_area + len(self._q) * (now - self._occ_last)
            elapsed = now - self._occ_t0
            occ = area / elapsed if elapsed > 0 else 0.0
            return {
                "capacity": self.capacity,
                "policy": self.policy,
                "size": len(self._q),
                "pushed": self.pushed,
                "popped": self.popped,
                "dropped": self.dropped,
                "put_stalls": self.put_stalls,
                "get_stalls": self.get_stalls,
                "mean_occupancy": occ,
            }
