"""Pluggable trajectory transports: one put/get/backpressure/counters
interface, two implementations.

``Transport`` is the API that ``TrajectoryQueue`` already speaks — it is
extracted here as an explicit interface so the learner and the actor
pools are written against *it*, and scaling steps become new transports
rather than new runtimes:

  InprocTransport   the existing in-process deque. Items are live jax
                    pytrees handed between threads: zero-copy, no serde.
  ShmTransport      a cross-process transport. Producers (actor
                    processes, or threads exercising the byte boundary)
                    move only serde-encoded contiguous buffers through a
                    bounded ``multiprocessing`` wire queue; a parent-side
                    drain thread decodes them and applies the configured
                    backpressure policy in a local ``TrajectoryQueue``.
  SocketTransport   (``socket_transport.py``) the same buffers as
                    length-prefixed, CRC-checked frames over TCP —
                    actors on other machines; per-connection drain
                    threads play the role ShmTransport's single drain
                    thread plays here.

Backpressure composes across the wire: with the ``block`` policy a slow
learner stalls the drain thread, the wire queue fills, and producer
``put``s time out in *their* process — real end-to-end backpressure, not
an unbounded pipe. With the drop policies the drain thread never blocks
for long (the local queue evicts/rejects), so the wire stays near-empty
and loss accounting happens where the policy lives.

Attribution hooks (all optional, parent-side):
  on_item(item)     decoded item accepted into the local queue
  on_reject(item)   decoded item rejected by drop_newest
  on_drop(item)     queued item evicted by drop_oldest
"""
from __future__ import annotations

import abc
import multiprocessing as mp
import queue as stdlib_queue
import threading
from typing import Any, Callable, Dict, Optional

from repro.distributed import serde
from repro.distributed.serde import TrajectoryItem
from repro.distributed.supervise import KillSafeEvent
from repro.distributed.tqueue import POLICIES, TrajectoryQueue

TRANSPORTS = ("inproc", "shm", "socket")


class Transport(abc.ABC):
    """Bounded MPMC trajectory channel with a backpressure policy.

    ``rejects_at_put`` tells producers whether a ``put`` returning False
    under drop_newest means *this item was rejected* (in-process queue)
    or merely *the wire is momentarily full, retry* (cross-process
    transport, where policy decisions happen at the drain side and are
    reported through the attribution hooks).
    """

    capacity: int
    policy: str
    rejects_at_put = True

    @abc.abstractmethod
    def put(self, item: Any, timeout: Optional[float] = None,
            count_stall: bool = True) -> bool: ...

    @abc.abstractmethod
    def get(self, timeout: Optional[float] = None) -> Optional[Any]: ...

    @abc.abstractmethod
    def get_nowait(self) -> Optional[Any]: ...

    @abc.abstractmethod
    def requeue_front(self, item: Any) -> None: ...

    @abc.abstractmethod
    def close(self) -> None: ...

    @property
    @abc.abstractmethod
    def closed(self) -> bool: ...

    @abc.abstractmethod
    def __len__(self) -> int: ...

    @abc.abstractmethod
    def snapshot(self) -> Dict[str, Any]: ...


class InprocTransport(TrajectoryQueue, Transport):
    """The in-process transport: the bounded deque, unchanged. Items stay
    live pytrees — no serialization, no copies."""


# TrajectoryQueue predates the interface and satisfies it structurally;
# let isinstance(queue, Transport) hold for plain instances too.
Transport.register(TrajectoryQueue)


class ShmProducer:
    """Picklable producer handle for a ``ShmTransport``: what an actor
    process receives. Moves opaque byte buffers; never touches jax."""

    def __init__(self, wire: Any, stop_event: Any):
        self._wire = wire
        self._stop = stop_event

    def send(self, buf: bytes, timeout: float = 0.1) -> bool:
        """Offer one encoded buffer; False = wire full (retry) or
        shutting down (check ``stopped``)."""
        if self._stop.is_set():
            return False
        try:
            self._wire.put(buf, timeout=timeout)
            return True
        except stdlib_queue.Full:
            return False
        except (ValueError, OSError):        # wire closed under us
            return False

    @property
    def stopped(self) -> bool:
        return self._stop.is_set()


class ShmTransport(Transport):
    """Cross-process transport: serialized buffers over a bounded
    ``multiprocessing`` queue, decoded and policy-filtered parent-side.

    The parent (learner) side is a full ``Transport``; producers use
    either ``put`` (same-process threads: encode + wire) or the picklable
    ``producer()`` handle (actor processes: wire only, the caller
    encodes). ``spawn`` is pinned so linux and macos behave identically.
    """

    rejects_at_put = False

    def __init__(self, capacity: int = 8, policy: str = "block",
                 wire_capacity: Optional[int] = None, registry=None,
                 wire_codec: str = serde.DEFAULT_CODEC):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got "
                             f"{policy!r}")
        self.capacity = capacity
        self.policy = policy
        # producers encode with this codec: same-process ``put`` applies
        # it here; actor processes receive it in their spawn config
        self.wire_codec = serde.check_codec(wire_codec)
        self._ctx = mp.get_context("spawn")
        # kill-safe: actor children share this flag and may be
        # SIGKILLed mid-check; mp.Event's internal lock would stay
        # held by the corpse and deadlock close()
        self._stop = KillSafeEvent(self._ctx)
        self._wire = self._ctx.Queue(maxsize=wire_capacity or max(2, capacity // 4))
        self._inner = TrajectoryQueue(capacity, policy, registry=registry)
        self.registry = self._inner.registry
        self.on_item: Optional[Callable[[TrajectoryItem], None]] = None
        self.on_reject: Optional[Callable[[TrajectoryItem], None]] = None
        self._closed = False
        self._discard = False
        self._close_lock = threading.Lock()
        self.wire_received = 0          # buffers decoded parent-side
        self.wire_bytes = 0             # payload volume moved
        self.wire_raw_bytes = 0         # raw leaf bytes those carried
        self.wire_put_stalls = 0        # parent-side put timeouts
        self.drain_errors: list = []    # decode failures (torn frames)
        self._drain = threading.Thread(target=self._drain_loop,
                                       name="shm-drain", daemon=True)
        self._drain.start()

    # ------------------------------------------------------------------
    # eviction attribution passes straight through to the local queue

    @property
    def on_drop(self):
        return self._inner.on_drop

    @on_drop.setter
    def on_drop(self, fn):
        self._inner.on_drop = fn

    # ------------------------------------------------------------------
    # producer side

    def producer(self) -> ShmProducer:
        return ShmProducer(self._wire, self._stop)

    def put(self, item: TrajectoryItem, timeout: Optional[float] = None,
            count_stall: bool = True) -> bool:
        """Same-process producer path: encode and offer to the wire.
        False means the wire is full (retry) or the transport is closed —
        drop_newest rejections surface via ``on_reject``, not here."""
        if self._stop.is_set():
            return False
        buf = serde.encode_item(item, codec=self.wire_codec)
        try:
            self._wire.put(buf, timeout=timeout)
            return True
        except stdlib_queue.Full:
            if count_stall:
                self.wire_put_stalls += 1
            return False
        except (ValueError, OSError):
            return False

    # ------------------------------------------------------------------
    # drain: wire bytes -> decoded items -> policy queue

    def _drain_loop(self) -> None:
        while not self._stop.is_set():
            try:
                buf = self._wire.get(timeout=0.1)
            except stdlib_queue.Empty:
                continue
            except (EOFError, OSError):
                break
            self.wire_received += 1
            self.wire_bytes += len(buf)
            if self._discard:
                continue    # shutdown: keep the wire flowing, drop data
            try:
                item = serde.decode_item(buf)
            except Exception as e:  # torn frame (e.g. a killed producer)
                self.drain_errors.append(repr(e))
                continue
            self.wire_raw_bytes += serde.tree_nbytes(item.data)
            while not self._stop.is_set() and not self._discard:
                if self._inner.put(item, timeout=0.1):
                    if self.on_item is not None:
                        self.on_item(item)
                    break
                # the closed check must come FIRST: a put that failed
                # because close()/begin_shutdown() raced us is shutdown
                # discard, and attributing it as a drop_newest rejection
                # would charge the producing actor for a loss the policy
                # never decided (found by the chaos harness's shutdown
                # sweep; regression-tested in test_transport.py)
                if self._inner.closed or self._discard:
                    break
                if self._inner.policy == "drop_newest":
                    if self.on_reject is not None:
                        self.on_reject(item)
                    break                   # genuine policy rejection
                # block policy: local queue full, learner slow — stall
                # here so the wire fills and producers feel it

    # ------------------------------------------------------------------
    # consumer side: delegate to the local policy queue

    def get(self, timeout: Optional[float] = None):
        return self._inner.get(timeout)

    def get_nowait(self):
        return self._inner.get_nowait()

    def requeue_front(self, item: TrajectoryItem) -> None:
        self._inner.requeue_front(item)

    # ------------------------------------------------------------------

    def begin_shutdown(self) -> None:
        """Enter discard mode: the drain thread keeps *consuming* the
        wire but drops everything. Producer processes winding down can
        always flush their queue feeders (a feeder killed mid-write into
        a full pipe would tear a frame for every later reader), so they
        exit promptly and cleanly. The local queue closes so learner-side
        consumers drain what's left and stop. Call this before joining
        producer processes; call ``close`` after."""
        self._discard = True
        self._inner.close()

    def close(self) -> None:
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
        self.begin_shutdown()
        self._stop.set()
        self._drain.join(timeout=5.0)
        # sweep whatever raced past the drain thread, then release the
        # queue's feeder resources without waiting on it at exit
        try:
            while True:
                self._wire.get_nowait()
        except (stdlib_queue.Empty, EOFError, OSError):
            pass
        self._wire.close()
        self._wire.cancel_join_thread()

    @property
    def closed(self) -> bool:
        return self._stop.is_set()

    def __len__(self) -> int:
        return len(self._inner)

    def snapshot(self) -> Dict[str, Any]:
        snap = self._inner.snapshot()
        snap.update({
            "transport": "shm",
            "wire_codec": self.wire_codec,
            "wire_received": self.wire_received,
            "wire_bytes": self.wire_bytes,
            "traj_wire_bytes": self.wire_bytes,
            "traj_raw_bytes": self.wire_raw_bytes,
            "bytes_per_frame": (self.wire_bytes / self.wire_received
                                if self.wire_received else 0.0),
            "wire_compression": (self.wire_raw_bytes / self.wire_bytes
                                 if self.wire_bytes else 1.0),
            "wire_put_stalls": self.wire_put_stalls,
            "drain_errors": len(self.drain_errors),
        })
        return snap


def make_transport(kind: str, capacity: int, policy: str,
                   **kw: Any) -> Transport:
    """``kw`` passes transport-specific options through (the socket
    transport's ``listen`` address / ``max_actors``)."""
    if kind == "inproc":
        return InprocTransport(capacity, policy, **kw)
    if kind == "shm":
        return ShmTransport(capacity, policy, **kw)
    if kind == "socket":
        # deferred import: the socket transport is its own module so
        # this one stays import-light for producer children
        from repro.distributed.socket_transport import SocketTransport
        return SocketTransport(capacity, policy, **kw)
    raise ValueError(f"transport must be one of {TRANSPORTS}, got {kind!r}")
