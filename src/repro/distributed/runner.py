"""The actor loop bodies, extracted so one implementation drives both
thread workers (``ActorPool``) and process workers (``ProcessActorPool``).

Two actor modes share this module:

``run_actor_loop`` is the paper's self-contained actor (§3): pull
current params, run one jitted n-step unroll against a private env
batch, stamp the trajectory with the parameter version it was acted
with, hand it to the transport. What varies between backends is only
*how* params arrive and *where* the trajectory goes:

  threads     pull = ParameterStore.pull (shared memory, zero-copy);
              emit = Transport.put of the live pytree.
  processes   pull = request/reply over a pipe against the parent's
              param server (serde-encoded, cached per version);
              emit = serde-encode + wire put of the byte buffer.

``run_inference_actor_loop`` is the dynamic-batching variant (§3.1):
the actor holds **no parameters at all** — it steps its env batch on
the host, submits each per-step observation batch to the shared
``InferenceService`` (which batches across actors on the learner's
device), and assembles the returned actions/log-probs/recurrent states
into the same trajectory layout the unroll produces. Thread clients
talk to the service in-process; process clients ship serde frames over
a wire.

Each worker derives its RNG stream from ``fold_in(seed, actor_id)`` —
identical across backends, so a thread-backend run and a process-backend
run with the same seed act out the same per-actor randomness. The
``actor_id`` here is always the *global* slot id: a learner group
shards the run's slots over its learners (pool ``slot_base``), and
because the loop bodies fold in the global id, actor g's randomness —
and therefore its env-seed stream — is byte-identical however the
slots are sharded.
"""
from __future__ import annotations

import time
import traceback
from typing import Any, Callable, List, Optional, Tuple

PyTree = Any


def run_actor_loop(
    *,
    actor_id: int,
    builder: Tuple[Callable, Callable],
    seed: int,
    pull_params: Callable[[], Optional[Tuple[PyTree, int]]],
    emit: Callable[[Any], bool],
    should_stop: Callable[[], bool],
    on_unroll: Optional[Callable[[], None]] = None,
    trace_every: Optional[int] = None,
) -> None:
    """Drive one actor until ``should_stop`` or a channel closes.

    ``pull_params`` returns (params, version) or None on shutdown.
    ``emit`` owns backpressure/retry/accounting and returns False only
    when the worker should exit. ``on_unroll`` fires after each finished
    (host-materialized) unroll — the hook for frame counters.

    ``trace_every`` > 0 samples every Nth unroll for the flight
    recorder: the item carries a stamp dict (``u0``/``u1`` here; the
    serde/transport layers add theirs downstream). Defaults to the
    ``REPRO_TRACE_EVERY`` env var so spawned actor children inherit the
    sampling rate without any pipe-protocol change; 0 disables.
    """
    import os

    import jax  # deferred: keeps this module importable without jax

    from repro.distributed.serde import TrajectoryItem

    if trace_every is None:
        try:
            trace_every = int(os.environ.get("REPRO_TRACE_EVERY", "0"))
        except ValueError:
            trace_every = 0

    init_fn, unroll = builder
    base = jax.random.fold_in(jax.random.key(seed), actor_id)
    carry = init_fn(jax.random.fold_in(base, 1))
    idx = 0
    while not should_stop():
        pulled = pull_params()
        if pulled is None:
            break
        params, version = pulled
        idx += 1
        sampled = bool(trace_every) and idx % trace_every == 0
        u0 = time.monotonic() if sampled else 0.0
        carry, traj = unroll(params, carry)
        # materialise before enqueue: backpressure must reflect finished
        # work, not a ballooning async dispatch queue
        traj = jax.block_until_ready(traj)
        if on_unroll is not None:
            on_unroll()
        now = time.monotonic()
        tr = {"u0": u0, "u1": now} if sampled else None
        item = TrajectoryItem(traj, version, actor_id, now, tr)
        if not emit(item):
            break


def assemble_inference_traj(steps: List[dict], boot: dict,
                            init_lstm: Tuple[Any, Any], icfg) -> dict:
    """Package one unroll's per-step records into the learner's
    trajectory layout — the exact shape ``core.actor``'s ``_finalize``
    produces (batch-major arrays, bootstrap step appended to the
    observation-side keys, the unroll's *initial* LSTM state attached).
    Shared by the thread-mode driver and the process actor loop so the
    layout cannot drift between backends.

    Leaves may be numpy or (possibly still-lazy) device arrays: host
    stacking forces/views them — ~20x cheaper than the equivalent chain
    of tiny XLA stack/concat dispatches, and on CPU the conversions are
    views by the time the unroll ends. Every emitted leaf is numpy, so
    learner-side stacking takes the staged-buffer path whichever
    transport carries the item.

    ``steps[t]`` keys: obs_image/last_action/last_reward/done_in (the
    step's *inputs*), action/reward/done/behaviour_logprob (its
    outputs). ``boot``: the post-final-step obs_image/last_action/
    last_reward/done."""
    import numpy as np

    def col(k):
        return np.stack([np.asarray(s[k]) for s in steps], axis=1)

    def col_boot(k, final):
        return np.concatenate([col(k), np.asarray(final)[:, None]],
                              axis=1)

    step_dones = col("done")
    return {
        "actions": col("action"),
        "rewards": col("reward"),
        "discounts": (icfg.discount *
                      (1.0 - step_dones.astype(np.float32))
                      ).astype(np.float32),
        "behaviour_logprob": col("behaviour_logprob"),
        "done": step_dones,
        "obs_image": col_boot("obs_image", boot["obs_image"]),
        "last_action": col_boot("last_action", boot["last_action"]),
        "last_reward": col_boot("last_reward", boot["last_reward"]),
        "done_in": col_boot("done_in", boot["done"]),
        "lstm_state": (np.asarray(init_lstm[0]),
                       np.asarray(init_lstm[1])),
    }


class _ActingState:
    """Per logical-actor (or per pipeline-stream) carry for the
    inference acting loops — everything the threaded layout would keep
    on an actor thread's stack."""

    __slots__ = ("uid", "client", "state", "obs_image", "last_action",
                 "last_reward", "done", "h", "c", "key", "ukey",
                 "steps", "version", "handle")


def _make_inference_env_fns(env, n: int):
    """The two jitted env drivers every inference acting loop shares."""
    import jax

    @jax.jit
    def reset_batch(key):
        keys = jax.random.split(key, n)
        state = jax.vmap(env.reset)(keys)
        return state, jax.vmap(env.observe)(state)

    @jax.jit
    def step_batch(state, action, key, t):
        # fold the step index in here: deriving per-step keys outside
        # would cost one extra device op on every step's critical path
        keys = jax.random.split(jax.random.fold_in(key, t), n)
        state, ts = jax.vmap(env.step)(state, action, keys)
        # only what the service request / trajectory needs: XLA dead-
        # code-eliminates the rest of the TimeStep (e.g. obs_token)
        return state, (ts.obs_image, ts.reward, ts.done)

    return reset_batch, step_batch


def _init_acting_state(uid, base_key, reset_batch, arch_cfg, n: int,
                       conv, client=None) -> _ActingState:
    import jax
    import numpy as np

    from repro.models import lstm as lstm_lib

    st = _ActingState()
    st.uid = uid
    st.client = client
    st.state, ts = reset_batch(jax.random.fold_in(base_key, 1))
    st.obs_image = conv(ts.obs_image)
    st.last_action = np.zeros((n,), np.int32)
    st.last_reward = np.zeros((n,), np.float32)
    st.done = np.zeros((n,), bool)
    st.h, st.c = (conv(x) for x in
                  lstm_lib.lstm_zero_state(n, arch_cfg.lstm_width))
    st.key = jax.random.fold_in(base_key, 2)
    return st


def _acting_request(st: _ActingState) -> dict:
    return {"obs_image": st.obs_image, "last_action": st.last_action,
            "last_reward": st.last_reward, "done": st.done,
            "lstm_h": st.h, "lstm_c": st.c}


def _acting_boot(st: _ActingState) -> dict:
    return {"obs_image": st.obs_image, "last_action": st.last_action,
            "last_reward": st.last_reward, "done": st.done}


def _record_reply_and_step(st: _ActingState, reply, step_batch, t: int,
                           conv) -> None:
    """The shared per-step bookkeeping: stamp the first-step version,
    advance the recurrent state from the reply, step the envs, record
    the step, carry forward."""
    import numpy as np

    if st.version is None:
        st.version = reply.param_version
    action = conv(reply.action)
    st.h = conv(reply.lstm_state[0])
    st.c = conv(reply.lstm_state[1])
    st.state, (obs_image, reward, step_done) = step_batch(
        st.state, action, st.ukey, np.int32(t))
    st.steps.append({
        "obs_image": st.obs_image, "last_action": st.last_action,
        "last_reward": st.last_reward, "done_in": st.done,
        "action": action, "reward": conv(reward),
        "done": conv(step_done),
        "behaviour_logprob": conv(reply.logprob)})
    st.obs_image = conv(obs_image)
    st.last_action = action
    st.last_reward = st.steps[-1]["reward"]
    st.done = st.steps[-1]["done"]


def run_inference_actor_loop(
    *,
    actor_id: int,
    env,
    arch_cfg,
    icfg,
    num_envs: int,
    seed: int,
    clients: List[Any],
    emit: Callable[[Any], bool],
    should_stop: Callable[[], bool],
    on_unroll: Optional[Callable[[], None]] = None,
    trace_every: Optional[int] = None,
) -> None:
    """Drive one *inference-mode* actor: host-side env stepping against
    the shared batched-inference service.

    ``clients`` is one service client per **pipeline stream**: the env
    batch is split evenly across them, and the streams are software-
    pipelined — while one stream's inference request is in flight (in a
    flush on the learner's device), the actor env-steps the other
    stream. With a single client the loop degenerates to the plain
    submit/step alternation. Each client must expose
    ``submit_async(request) -> handle | None`` and
    ``wait(handle) -> InferenceReply | None`` (None = service shut
    down) plus ``pause``/``resume``.

    The caller's ``emit`` should pause/resume the clients around *long*
    blocks (transport backpressure): the service stops counting paused
    clients towards its all-clients-ready flush rule, so a
    learner-throttled actor never holds the others' batches hostage to
    the flush deadline. Short gaps (trajectory assembly, ~0.5ms)
    deliberately do NOT pause: fracturing the bucket costs more than
    the others waiting out a sub-millisecond straggler.

    The trajectory emitted recombines the streams along the batch axis
    and is bit-compatible with the unroll actor's layout
    (``assemble_inference_traj``). The item is stamped with the oldest
    param version of the unroll's first step across streams, so
    measured lag stays conservative. Per-step state is materialized
    numpy — the requests cross a serde wire anyway.

    ``trace_every`` samples every Nth unroll for the flight recorder,
    exactly like the unroll actor: the ``u0``/``u1`` stamps bracket the
    whole acting round (env steps + inference round-trips), so the
    7-span lifecycle covers inference-mode items too. Defaults to the
    ``REPRO_TRACE_EVERY`` env var; 0 disables.
    """
    import os

    import jax
    import numpy as np

    from repro.distributed.serde import TrajectoryItem

    if trace_every is None:
        try:
            trace_every = int(os.environ.get("REPRO_TRACE_EVERY", "0"))
        except ValueError:
            trace_every = 0

    t_len = icfg.unroll_length
    n_streams = len(clients)
    if num_envs % n_streams:
        raise ValueError(f"num_envs={num_envs} must divide evenly over "
                         f"{n_streams} pipeline streams")
    n_sub = num_envs // n_streams
    base = jax.random.fold_in(jax.random.key(seed), actor_id)
    conv = np.asarray
    reset_batch, step_batch = _make_inference_env_fns(env, n_sub)

    streams = [
        _init_acting_state(s, jax.random.fold_in(base, s), reset_batch,
                           arch_cfg, n_sub, conv, client=client)
        for s, client in enumerate(clients)]

    unroll_idx = 0
    while not should_stop():
        unroll_idx += 1
        sampled = bool(trace_every) and unroll_idx % trace_every == 0
        u0 = time.monotonic() if sampled else 0.0
        init_lstm = [(st.h, st.c) for st in streams]
        for st in streams:
            st.steps = []
            st.version = None
            st.ukey = jax.random.fold_in(st.key, unroll_idx)
            if n_streams > 1:
                st.handle = st.client.submit_async(_acting_request(st))
        for t in range(t_len):
            for st in streams:
                if n_streams > 1:
                    # while this wait blocks, the other streams'
                    # requests are pending service-side and our env
                    # step below overlaps their flush
                    reply = st.client.wait(st.handle)
                else:
                    # single stream: the blocking path keeps
                    # leader-executed flushes (no service-thread wake
                    # on the critical path)
                    reply = st.client.infer(_acting_request(st))
                if reply is None:
                    return              # service shut down mid-unroll
                _record_reply_and_step(st, reply, step_batch, t, conv)
                if n_streams > 1 and t + 1 < t_len:
                    st.handle = st.client.submit_async(_acting_request(st))

        trajs = [assemble_inference_traj(st.steps, _acting_boot(st),
                                         init_lstm[s], icfg)
                 for s, st in enumerate(streams)]
        traj = (trajs[0] if n_streams == 1 else
                jax.tree.map(lambda *xs: np.concatenate(xs, axis=0),
                             *trajs))
        version = min(st.version for st in streams)
        if on_unroll is not None:
            on_unroll()
        now = time.monotonic()
        tr = {"u0": u0, "u1": now} if sampled else None
        if not emit(TrajectoryItem(traj, version, actor_id, now, tr)):
            break


def run_inference_driver_loop(
    *,
    actor_ids: List[int],
    env,
    arch_cfg,
    icfg,
    num_envs: int,
    seed: int,
    service,
    emit: Callable[[int, Any], bool],
    should_stop: Callable[[], bool],
    on_unroll: Optional[Callable[[int], None]] = None,
    trace_every: Optional[int] = None,
) -> None:
    """Drive ALL thread-mode inference actors from one thread.

    Under the GIL, per-actor threads buy an inference-mode actor
    nothing: the service does the policy compute, env-step dispatches
    are brief, and what remains is pure glue — which N threads only
    serialize anyway, paying an Event wake-up per actor per step on the
    critical path. This driver multiplexes the logical actors instead:
    submit every actor's per-step request, execute the flush inline
    (``service.drive_flushes``), dispatch every env step (lazily — the
    results are only forced by the next flush or the unroll assembly),
    repeat. A full acting cycle has zero cross-thread handoffs.

    Each logical actor keeps exactly the identity it has under the
    per-thread layout: its own env batch, its own
    ``fold_in(seed, actor_id)`` RNG stream, its own trajectory stream
    stamped with its ``actor_id``. Emits block on transport
    backpressure, which stalls all acting — the same throttling the
    thread-per-actor layout converges to, reached sooner.

    ``trace_every`` samples every Nth unroll (per logical actor) for
    the flight recorder, mirroring the other loop bodies.
    """
    import os

    import jax

    from repro.distributed.serde import TrajectoryItem

    if trace_every is None:
        try:
            trace_every = int(os.environ.get("REPRO_TRACE_EVERY", "0"))
        except ValueError:
            trace_every = 0

    t_len = icfg.unroll_length
    reset_batch, step_batch = _make_inference_env_fns(env, num_envs)
    # identity conv: env-step outputs stay lazy device values — the
    # next flush (or the unroll assembly) forces them off this thread's
    # critical path. Replies are already numpy (materialized once,
    # service-side).
    conv = (lambda x: x)

    actors = [
        _init_acting_state(
            aid, jax.random.fold_in(jax.random.key(seed), aid),
            reset_batch, arch_cfg, num_envs, conv)
        for aid in actor_ids]

    unroll_idx = 0
    while not should_stop():
        unroll_idx += 1
        sampled = bool(trace_every) and unroll_idx % trace_every == 0
        u0 = time.monotonic() if sampled else 0.0
        init_lstm = {a.uid: (a.h, a.c) for a in actors}
        for a in actors:
            a.steps = []
            a.version = None
            a.ukey = jax.random.fold_in(a.key, unroll_idx)
        for t in range(t_len):
            for a in actors:
                a.handle = service.submit_async(_acting_request(a))
                if a.handle is None:
                    return                  # service shut down
            service.drive_flushes()
            for a in actors:
                if not a.handle.event.is_set():     # frontend raced us
                    reply = service.wait(a.handle)
                else:
                    reply = a.handle.slot[0]
                if reply is None:
                    return
                _record_reply_and_step(a, reply, step_batch, t, conv)

        for a in actors:
            # env-step leaves recorded above may still be lazy device
            # values: assemble_inference_traj forces them (free views
            # by now — the flushes consumed their upstream chains)
            traj = assemble_inference_traj(a.steps, _acting_boot(a),
                                           init_lstm[a.uid], icfg)
            if on_unroll is not None:
                on_unroll(a.uid)
            now = time.monotonic()
            tr = {"u0": u0, "u1": now} if sampled else None
            if not emit(a.uid, TrajectoryItem(traj, a.version, a.uid,
                                              now, tr)):
                return


# ---------------------------------------------------------------------------
# serialized-actor scaffolding, shared by the pipe (process) and socket
# (remote) backends: the loop bodies above never see the wire — what
# varies is only how params arrive (``pull_msg``) and where encoded
# trajectory buffers go (``send_buf``)


def run_serialized_unroll_actor(*, actor_id: int, env_name: str,
                                arch_cfg, icfg, num_envs: int,
                                seed: int,
                                send_buf: Callable[[bytes], bool],
                                pull_msg: Callable[[int],
                                                   Optional[Tuple]],
                                stop,
                                wire_codec: str = "none") -> None:
    """One unroll-mode actor on the far side of a serialized boundary.

    ``pull_msg(have_version)`` returns ``("params", version, buf)``,
    ``("keep",)``, ``("stop",)`` or None — a pipe wrapper or a socket
    pull; raising any channel error also means stop. ``send_buf(buf)``
    blocks until the encoded trajectory is accepted by the wire (its
    retry/backpressure/reconnect discipline lives with the channel) and
    returns False only when shutting down. ``stop`` is any Event-alike
    with ``is_set``/``wait``.

    The unroll stays on the critical path alone: a *subscriber* thread
    refreshes params in the background (the loop never waits on the
    channel once the first version has landed), and a *sender* thread
    owns encode + send behind a depth-1 buffer — enough to overlap the
    send with the next unroll, shallow enough that wire backpressure
    still stalls the actor within two trajectories."""
    import queue as stdlib_queue
    import threading

    import jax
    import numpy as np

    from repro.core import actor as actor_lib
    from repro.data.envs import make_env
    from repro.distributed import serde

    env = make_env(env_name)
    builder = actor_lib.build_actor(env, arch_cfg, icfg, num_envs)
    cache = {"params": None, "version": -1, "dead": False}
    cache_lock = threading.Lock()
    fresh = threading.Event()

    def subscribe():
        # version-gated pub/sub: ask for anything newer than we hold
        # (a "keep" reply costs one tiny message), at a bounded rate —
        # the throttle caps both server traffic and this child's
        # decode+upload work; params are at most ``interval`` stale,
        # which is exactly the off-policy gap V-trace corrects
        interval = 0.1
        # steady state decodes into one reused host mirror instead
        # of allocating a fresh params-sized tree per pull; the
        # first pull — or a structure change — takes the allocating
        # path. The device upload MUST be jnp.array (guaranteed
        # copy): jnp.asarray zero-copy *aliases* 64-byte-aligned
        # host buffers on the CPU backend (measured), and an
        # aliased param leaf would be torn by the next publish's
        # decode while the unroll reads it
        mirror = None
        while not stop.is_set():
            try:
                msg = pull_msg(cache["version"])
            except (EOFError, OSError, BrokenPipeError, ValueError):
                # includes the channel closing under us during shutdown
                break
            if msg is None or msg[0] == "stop":
                break
            if msg[0] == "params":
                _, version, buf = msg
                # a retried pull can deliver a stale queued reply:
                # installing an older version than we hold would step
                # the behaviour policy backwards
                if version > cache["version"]:
                    if mirror is not None:
                        try:
                            serde.decode_tree_into(buf, mirror)
                        except serde.SerdeError:
                            mirror = None
                    if mirror is None:
                        mirror, _ = serde.decode_tree(buf, copy=True)
                    params = jax.tree.map(jax.numpy.array, mirror)
                    with cache_lock:
                        cache["params"] = params
                        cache["version"] = version
                    fresh.set()
            if stop.wait(interval):
                break
        with cache_lock:
            cache["dead"] = True
        fresh.set()

    def pull_params():
        while not fresh.wait(timeout=0.2):
            if stop.is_set():
                return None
        with cache_lock:
            if cache["dead"] and cache["params"] is None:
                return None
            return cache["params"], cache["version"]

    outbox: stdlib_queue.Queue = stdlib_queue.Queue(maxsize=1)

    def send_loop():
        while True:
            try:
                item = outbox.get(timeout=0.1)
            except stdlib_queue.Empty:
                if stop.is_set():
                    return
                continue
            if item is None:
                return
            tr = item.trace
            if tr is not None:
                tr = dict(tr)
                tr["e0"] = time.monotonic()     # encode start; serde
                # stamps e1 itself once the payload bytes are built
            buf = serde.encode_item(serde.TrajectoryItem(
                jax.tree.map(np.asarray, item.data),
                item.param_version, item.actor_id, item.produced_at,
                tr), codec=wire_codec)
            if not send_buf(buf):
                return                  # channel says we are done

    def emit(item):
        while not stop.is_set():
            try:
                outbox.put(item, timeout=0.1)
                return True
            except stdlib_queue.Full:
                continue                # wire backpressure reached us
        return False

    sub = threading.Thread(target=subscribe, daemon=True,
                           name="param-subscriber")
    snd = threading.Thread(target=send_loop, daemon=True,
                           name="traj-sender")
    sub.start()
    snd.start()
    try:
        run_actor_loop(actor_id=actor_id, builder=builder, seed=seed,
                       pull_params=pull_params, emit=emit,
                       should_stop=stop.is_set)
    finally:
        try:
            outbox.put_nowait(None)
        except stdlib_queue.Full:
            pass
        snd.join(timeout=5.0)


def run_serialized_inference_actor(*, actor_id: int, env_name: str,
                                   arch_cfg, icfg, num_envs: int,
                                   seed: int,
                                   send_buf: Callable[[bytes], bool],
                                   infer_clients: List[Any],
                                   stop,
                                   wire_codec: str = "none") -> None:
    """One inference-mode actor on the far side of a serialized
    boundary: no parameters, no policy network — env stepping plus
    frames both ways (observation requests up, action replies down,
    finished trajectories out through ``send_buf``). ``infer_clients``
    is one service client per pipeline stream (pipe- or socket-backed;
    same surface). The trajectory sender runs behind the same depth-1
    outbox as the unroll worker, overlapping encode+send with the next
    unroll's inference round-trips."""
    import queue as stdlib_queue
    import threading

    from repro.data.envs import make_env
    from repro.distributed import serde

    for cl in infer_clients:
        cl.bind_stop(stop)
    env = make_env(env_name)
    outbox: stdlib_queue.Queue = stdlib_queue.Queue(maxsize=1)

    def send_loop():
        while True:
            try:
                item = outbox.get(timeout=0.1)
            except stdlib_queue.Empty:
                if stop.is_set():
                    return
                continue
            if item is None:
                return
            buf = serde.encode_item(item, codec=wire_codec)
            if not send_buf(buf):           # leaves already numpy
                return

    def emit(item):
        blocked = False
        try:
            while not stop.is_set():
                try:
                    outbox.put(item, timeout=0.1)
                    return True
                except stdlib_queue.Full:
                    # wire backpressure reached us: drop out of the
                    # service's ready rule while we wait
                    if not blocked:
                        blocked = True
                        for cl in infer_clients:
                            cl.pause()
                    continue
        finally:
            if blocked:
                for cl in infer_clients:
                    cl.resume()
        return False

    snd = threading.Thread(target=send_loop, daemon=True,
                           name="traj-sender")
    snd.start()
    try:
        run_inference_actor_loop(
            actor_id=actor_id, env=env, arch_cfg=arch_cfg, icfg=icfg,
            num_envs=num_envs, seed=seed, clients=infer_clients,
            emit=emit, should_stop=stop.is_set)
    finally:
        try:
            outbox.put_nowait(None)
        except stdlib_queue.Full:
            pass
        snd.join(timeout=5.0)
        for cl in infer_clients:
            cl.close()


# ---------------------------------------------------------------------------
# process worker entry point (spawn target — must be module-level)


def _tune_child_scheduling(actor_id: int) -> None:
    """Best-effort OS tuning for an actor child on a shared box: actors
    yield to the learner (the learner is the throughput constraint under
    backpressure — a niced actor loses nothing, it would have stalled on
    the queue anyway) and each child sticks to one core so four children
    don't migrate across, and thrash the caches of, every core the
    learner's train step is using. Pinning keys off the *global* slot
    id, so the actor shards of a learner group land on disjoint cores
    by construction (modulo wraparound on small hosts)."""
    import os
    # a small niceness wins: +3 keeps the learner ahead in the scheduler
    # without starving acting (larger values over-throttle producers on
    # small hosts); override via env for experiments
    nice_step = int(os.environ.get("REPRO_ACTOR_NICE", "3"))
    if nice_step:
        try:
            os.nice(nice_step)
        except OSError:  # pragma: no cover
            pass
    if os.environ.get("REPRO_ACTOR_PIN", "1") == "1":
        try:
            ncpu = os.cpu_count() or 1
            os.sched_setaffinity(0, {actor_id % ncpu})
        except (AttributeError, OSError):  # pragma: no cover
            pass


def _wire_send_buf(producer, stop_event) -> Callable[[bytes], bool]:
    """Adapt a ``ShmProducer``-style offer-with-timeout handle to the
    blocking ``send_buf`` contract the serialized actor bodies use."""
    def send_buf(buf: bytes) -> bool:
        while not stop_event.is_set():
            if producer.send(buf, timeout=0.1):
                return True
        return False
    return send_buf


def process_actor_main(actor_id: int, env_name: str, arch_cfg, icfg,
                       num_envs: int, seed: int, producer,
                       param_conn, stop_event,
                       wire_codec: str = "none") -> None:
    """Entry point of one actor *process*. Builds its own env batch and
    jit cache (nothing jax crosses the process boundary), subscribes to
    params by version from the parent's param server over the pipe, and
    ships serde-encoded trajectories through the wire — the loop,
    subscriber, and sender all live in ``run_serialized_unroll_actor``,
    shared verbatim with the socket (remote) backend."""
    try:
        _tune_child_scheduling(actor_id)

        def pull_msg(have_version):
            param_conn.send(("pull", actor_id, have_version))
            return param_conn.recv()

        run_serialized_unroll_actor(
            actor_id=actor_id, env_name=env_name, arch_cfg=arch_cfg,
            icfg=icfg, num_envs=num_envs, seed=seed,
            send_buf=_wire_send_buf(producer, stop_event),
            pull_msg=pull_msg, stop=stop_event, wire_codec=wire_codec)
    except BaseException:
        try:
            param_conn.send(("error", actor_id, traceback.format_exc()))
        except (EOFError, OSError, BrokenPipeError):
            pass
    finally:
        try:
            param_conn.close()
        except OSError:
            pass


def inference_actor_main(actor_id: int, env_name: str, arch_cfg, icfg,
                         num_envs: int, seed: int, producer,
                         infer_clients, ctrl_conn, stop_event,
                         wire_codec: str = "none") -> None:
    """Entry point of one *inference-mode* actor process: no parameters,
    no policy network — just env stepping plus serde frames both ways
    (observation requests up the shared wire, action replies back down
    per-stream private pipes, finished trajectories through the
    transport wire). ``infer_clients`` is one ``PipeInferenceClient``
    per pipeline stream; ``ctrl_conn`` is the control pipe to the
    parent's server thread, used only for error reports here (nothing
    to pull — the service owns the params). The loop body is
    ``run_serialized_inference_actor``, shared verbatim with the socket
    (remote) backend."""
    try:
        _tune_child_scheduling(actor_id)
        run_serialized_inference_actor(
            actor_id=actor_id, env_name=env_name, arch_cfg=arch_cfg,
            icfg=icfg, num_envs=num_envs, seed=seed,
            send_buf=_wire_send_buf(producer, stop_event),
            infer_clients=infer_clients, stop=stop_event,
            wire_codec=wire_codec)
    except BaseException:
        try:
            ctrl_conn.send(("error", actor_id, traceback.format_exc()))
        except (EOFError, OSError, BrokenPipeError):
            pass
    finally:
        try:
            ctrl_conn.close()
        except OSError:
            pass
