"""The actor loop body, extracted so one implementation drives both
thread workers (``ActorPool``) and process workers (``ProcessActorPool``).

The loop is the paper's actor (§3): pull current params, run one jitted
n-step unroll against a private env batch, stamp the trajectory with the
parameter version it was acted with, hand it to the transport. What
varies between backends is only *how* params arrive and *where* the
trajectory goes:

  threads     pull = ParameterStore.pull (shared memory, zero-copy);
              emit = Transport.put of the live pytree.
  processes   pull = request/reply over a pipe against the parent's
              param server (serde-encoded, cached per version);
              emit = serde-encode + wire put of the byte buffer.

Each worker derives its RNG stream from ``fold_in(seed, actor_id)`` —
identical across backends, so a thread-backend run and a process-backend
run with the same seed act out the same per-actor randomness.
"""
from __future__ import annotations

import time
import traceback
from typing import Any, Callable, Optional, Tuple

PyTree = Any


def run_actor_loop(
    *,
    actor_id: int,
    builder: Tuple[Callable, Callable],
    seed: int,
    pull_params: Callable[[], Optional[Tuple[PyTree, int]]],
    emit: Callable[[Any], bool],
    should_stop: Callable[[], bool],
    on_unroll: Optional[Callable[[], None]] = None,
) -> None:
    """Drive one actor until ``should_stop`` or a channel closes.

    ``pull_params`` returns (params, version) or None on shutdown.
    ``emit`` owns backpressure/retry/accounting and returns False only
    when the worker should exit. ``on_unroll`` fires after each finished
    (host-materialized) unroll — the hook for frame counters.
    """
    import jax  # deferred: keeps this module importable without jax

    from repro.distributed.serde import TrajectoryItem

    init_fn, unroll = builder
    base = jax.random.fold_in(jax.random.key(seed), actor_id)
    carry = init_fn(jax.random.fold_in(base, 1))
    while not should_stop():
        pulled = pull_params()
        if pulled is None:
            break
        params, version = pulled
        carry, traj = unroll(params, carry)
        # materialise before enqueue: backpressure must reflect finished
        # work, not a ballooning async dispatch queue
        traj = jax.block_until_ready(traj)
        if on_unroll is not None:
            on_unroll()
        item = TrajectoryItem(traj, version, actor_id, time.monotonic())
        if not emit(item):
            break


# ---------------------------------------------------------------------------
# process worker entry point (spawn target — must be module-level)


def _tune_child_scheduling(actor_id: int) -> None:
    """Best-effort OS tuning for an actor child on a shared box: actors
    yield to the learner (the learner is the throughput constraint under
    backpressure — a niced actor loses nothing, it would have stalled on
    the queue anyway) and each child sticks to one core so four children
    don't migrate across, and thrash the caches of, every core the
    learner's train step is using."""
    import os
    # a small niceness wins: +3 keeps the learner ahead in the scheduler
    # without starving acting (larger values over-throttle producers on
    # small hosts); override via env for experiments
    nice_step = int(os.environ.get("REPRO_ACTOR_NICE", "3"))
    if nice_step:
        try:
            os.nice(nice_step)
        except OSError:  # pragma: no cover
            pass
    if os.environ.get("REPRO_ACTOR_PIN", "1") == "1":
        try:
            ncpu = os.cpu_count() or 1
            os.sched_setaffinity(0, {actor_id % ncpu})
        except (AttributeError, OSError):  # pragma: no cover
            pass


def process_actor_main(actor_id: int, env_name: str, arch_cfg, icfg,
                       num_envs: int, seed: int, producer,
                       param_conn, stop_event) -> None:
    """Entry point of one actor *process*. Builds its own env batch and
    jit cache (nothing jax crosses the process boundary), subscribes to
    params by version from the parent's param server, and ships
    serde-encoded trajectories through the wire.

    The unroll is kept on the critical path alone: a *subscriber* thread
    refreshes params in the background (the loop never waits on the
    pipe once the first version has landed), and a *sender* thread owns
    encode + wire put behind a depth-1 buffer — enough to overlap the
    send with the next unroll, shallow enough that wire backpressure
    still stalls the actor within two trajectories."""
    import queue as stdlib_queue
    import threading

    try:
        _tune_child_scheduling(actor_id)
        import jax
        import numpy as np

        from repro.core import actor as actor_lib
        from repro.data.envs import make_env
        from repro.distributed import serde

        env = make_env(env_name)
        builder = actor_lib.build_actor(env, arch_cfg, icfg, num_envs)
        cache = {"params": None, "version": -1, "dead": False}
        cache_lock = threading.Lock()
        fresh = threading.Event()

        def subscribe():
            # version-gated pub/sub: ask for anything newer than we hold
            # (a "keep" reply costs one tiny message), at a bounded rate —
            # the throttle caps both server traffic and this child's
            # decode+upload work; params are at most ``interval`` stale,
            # which is exactly the off-policy gap V-trace corrects
            interval = 0.1
            while not stop_event.is_set():
                try:
                    param_conn.send(("pull", actor_id, cache["version"]))
                    msg = param_conn.recv()
                except (EOFError, OSError, BrokenPipeError, ValueError):
                    # includes the main thread closing the conn under us
                    # during shutdown
                    break
                if msg[0] == "stop":
                    break
                if msg[0] == "params":
                    _, version, buf = msg
                    tree, _ = serde.decode_tree(buf, copy=True)
                    params = jax.tree.map(jax.numpy.asarray, tree)
                    with cache_lock:
                        cache["params"] = params
                        cache["version"] = version
                    fresh.set()
                if stop_event.wait(interval):
                    break
            with cache_lock:
                cache["dead"] = True
            fresh.set()

        def pull_params():
            while not fresh.wait(timeout=0.2):
                if stop_event.is_set():
                    return None
            with cache_lock:
                if cache["dead"] and cache["params"] is None:
                    return None
                return cache["params"], cache["version"]

        outbox: stdlib_queue.Queue = stdlib_queue.Queue(maxsize=1)

        def send_loop():
            while True:
                try:
                    item = outbox.get(timeout=0.1)
                except stdlib_queue.Empty:
                    if stop_event.is_set():
                        return
                    continue
                if item is None:
                    return
                buf = serde.encode_item(serde.TrajectoryItem(
                    jax.tree.map(np.asarray, item.data),
                    item.param_version, item.actor_id, item.produced_at))
                while not stop_event.is_set():
                    if producer.send(buf, timeout=0.1):
                        break

        def emit(item):
            while not stop_event.is_set():
                try:
                    outbox.put(item, timeout=0.1)
                    return True
                except stdlib_queue.Full:
                    continue            # wire backpressure reached us
            return False

        sub = threading.Thread(target=subscribe, daemon=True,
                               name="param-subscriber")
        snd = threading.Thread(target=send_loop, daemon=True,
                               name="traj-sender")
        sub.start()
        snd.start()
        try:
            run_actor_loop(actor_id=actor_id, builder=builder, seed=seed,
                           pull_params=pull_params, emit=emit,
                           should_stop=stop_event.is_set)
        finally:
            try:
                outbox.put_nowait(None)
            except stdlib_queue.Full:
                pass
            snd.join(timeout=5.0)
    except BaseException:
        try:
            param_conn.send(("error", actor_id, traceback.format_exc()))
        except (EOFError, OSError, BrokenPipeError):
            pass
    finally:
        try:
            param_conn.close()
        except OSError:
            pass
