"""Network serving layer on top of ``socket_transport``: everything a
*remote machine* needs beyond the raw trajectory pipe.

Three pieces:

  config codec          the learner ships the entire run configuration
                        (env name, ``ArchConfig``, ``ImpalaConfig``,
                        seed, actor id, mode) inside the CONFIG
                        handshake frame — a remote actor dials in
                        knowing only the learner's address. In a
                        learner group the handshake also carries the
                        ``shard_map`` (every learner's listen address),
                        and a learner whose shard is full *refuses with
                        that map*, so the client spills to a learner
                        with a free slot: dialing any one member of the
                        group is enough to land on the learner that
                        owns your slot.
  SocketInferenceFrontend / SocketInferenceClient
                        the ``InferenceService`` over TCP: observation
                        request frames ride the ctrl connection up,
                        action replies come back routed by client id —
                        a remote machine in inference mode holds *no
                        parameters at all*, only env stepping.
  remote actor entry    ``remote_actor_main`` drives one remote actor
                        end to end (handshake -> build env -> the same
                        loop bodies every other backend runs), and
                        ``remote_actor_child`` is its picklable spawn
                        target for loopback children.

Requests carry a monotonically increasing per-client ``seq``; replies
echo it. If the ctrl link dies with a request in flight, the client
resubmits on the fresh link and discards any reply whose seq is not the
one awaited — at-most-once delivery per step, so a reconnect can never
desynchronise the recurrent state an actor carries between steps.

Module-level imports stay jax-free: spawn re-imports this module in
every child before the child decides whether it needs a policy at all.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
import traceback
from typing import Any, Dict, List, Optional

from repro.configs.base import (ArchConfig, ImpalaConfig, MoEConfig,
                                RGLRUConfig, SSMConfig)
from repro.distributed import serde
from repro.distributed import socket_transport as st

_DATACLASSES = {cls.__name__: cls for cls in
                (ArchConfig, ImpalaConfig, MoEConfig, SSMConfig,
                 RGLRUConfig)}


# ---------------------------------------------------------------------------
# config codec: frozen config dataclasses <-> JSON-able trees


def cfg_to_jsonable(obj: Any) -> Any:
    """Encode nested config dataclasses/tuples into plain JSON types.
    Tuples are tagged so the round trip restores them exactly — frozen
    dataclasses are hashable (jit closes over them) only if their
    tuple-typed fields come back as tuples."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        name = type(obj).__name__
        if name not in _DATACLASSES:
            raise ValueError(f"unregistered config dataclass {name!r}")
        return {"__dc__": name,
                "fields": {f.name: cfg_to_jsonable(getattr(obj, f.name))
                           for f in dataclasses.fields(obj)}}
    if isinstance(obj, tuple):
        return {"__tuple__": [cfg_to_jsonable(v) for v in obj]}
    if isinstance(obj, list):
        return [cfg_to_jsonable(v) for v in obj]
    return obj


def cfg_from_jsonable(obj: Any) -> Any:
    if isinstance(obj, dict):
        if "__dc__" in obj:
            cls = _DATACLASSES[obj["__dc__"]]
            return cls(**{k: cfg_from_jsonable(v)
                          for k, v in obj["fields"].items()})
        if "__tuple__" in obj:
            return tuple(cfg_from_jsonable(v) for v in obj["__tuple__"])
        return {k: cfg_from_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [cfg_from_jsonable(v) for v in obj]
    return obj


def build_actor_config(*, env_name: str, arch_cfg: ArchConfig,
                       icfg: ImpalaConfig, num_envs: int, seed: int,
                       mode: str, infer_streams: int = 1
                       ) -> Dict[str, Any]:
    """The CONFIG-handshake payload (minus the server-assigned
    ``actor_id``): everything a remote machine needs to act."""
    return {
        "env": env_name,
        "arch": cfg_to_jsonable(arch_cfg),
        "icfg": cfg_to_jsonable(icfg),
        "num_envs": int(num_envs),
        "seed": int(seed),
        "mode": mode,
        "infer_streams": int(infer_streams),
    }


# ---------------------------------------------------------------------------
# inference service over sockets


class SocketInferenceFrontend:
    """Learner-side bridge: INFER_REQ frames (arriving on remote actors'
    ctrl connections) into ``InferenceService.submit``; replies are
    encoded once and sent back on the same connection, routed by client
    id in the frame's stream field. Mirrors ``ProcessFrontend``'s
    shutdown discipline: ``begin_shutdown`` answers every request with
    the stop sentinel so remote clients wind down promptly."""

    def __init__(self, service, transport: st.SocketTransport,
                 streams: int = 1):
        self._svc = service
        self._transport = transport
        self._streams = max(1, streams)
        self._paused_cids: set = set()
        # clients are counted on their FIRST request and uncounted when
        # their ctrl connection drops — the service's all-clients-ready
        # rule must track who can actually submit right now, not who
        # might eventually dial in (up-front counting would make every
        # batch wait out the flush timeout until the last remote
        # machine connects)
        self._seen_cids: set = set()
        self._cid_lock = threading.Lock()
        self._discard = False
        transport.handlers[st.KIND_INFER_REQ] = self._on_request
        transport.ctrl_handler = self._on_ctrl
        transport.on_ctrl_gone = self._on_ctrl_gone
        service.attach_frontend(self, num_clients=0)

    def _count_client(self, cid: int) -> None:
        with self._cid_lock:
            if cid in self._seen_cids:
                return
            self._seen_cids.add(cid)
        with self._svc._lock:
            self._svc._clients += 1

    def _on_ctrl_gone(self, actor_id: int) -> None:
        """The actor's ctrl link dropped: it can neither submit nor
        receive replies until it reconnects, so its clients leave the
        ready rule and any pause hints it left behind are cleared (a
        crashed-while-paused actor must not skew batches forever; on
        reconnect its first request re-counts it, and it re-pauses if
        still backpressured)."""
        for s in range(self._streams):
            cid = actor_id * self._streams + s
            with self._cid_lock:
                seen = cid in self._seen_cids
                self._seen_cids.discard(cid)
            if seen:
                self._svc._disconnect()
            if cid in self._paused_cids:
                self._paused_cids.discard(cid)
                self._svc._resume()

    def _reply_fn(self, chan: st.FrameChannel, cid: int, seq: int):
        import numpy as np

        def reply(r) -> None:
            if r is None:
                buf = b""                       # stop sentinel
            else:
                buf = serde.encode_tree(
                    {"action": np.asarray(r.action),
                     "logprob": np.asarray(r.logprob),
                     "lstm_h": np.asarray(r.lstm_state[0]),
                     "lstm_c": np.asarray(r.lstm_state[1])},
                    meta={"version": int(r.param_version),
                          "seq": int(seq)})
            # bounded send: this runs on the service's flush thread (or
            # a leader client's), shared by every actor — a partitioned
            # peer whose TCP buffer is full must not wedge the fleet's
            # inference. Past the deadline the link is marked dead and
            # the reply dropped; the client resubmits after reconnect.
            deadline = time.monotonic() + 5.0
            if not chan.send(st.KIND_INFER_REP, cid, buf,
                             stop=lambda: time.monotonic() > deadline):
                chan.close()    # wedged link: drop it, the client's
                # reconnect + resubmit machinery takes over

        return reply

    def _on_request(self, chan: st.FrameChannel, cid: int,
                    payload: bytes) -> None:
        try:
            data, meta = serde.decode_tree(payload)  # payload owns bytes
        except serde.SerdeError as e:
            self._svc.errors.append(e)
            return
        seq = int(meta.get("seq", 0))
        if self._discard or self._svc.closed:
            self._reply_fn(chan, cid, seq)(None)
            return
        self._count_client(cid)
        # submitted_at is stamped HERE, on the learner's clock: the
        # request's meta t0 is a *remote* CLOCK_MONOTONIC reading whose
        # origin is unrelated to ours — trusting it would make the
        # flush-timeout rule fire never (remote clock ahead) or always
        # (behind), destroying the dynamic batching cross-machine
        if not self._svc.submit(data, self._reply_fn(chan, cid, seq),
                                time.monotonic()):
            self._reply_fn(chan, cid, seq)(None)

    def _on_ctrl(self, cid: int, payload: bytes) -> None:
        # pause/resume hints, deduplicated per client id so repeated or
        # reordered frames never over-/under-count the paused total
        if payload == st.CTRL_PAUSE and cid not in self._paused_cids:
            self._paused_cids.add(cid)
            self._svc._pause()
        elif payload == st.CTRL_RESUME and cid in self._paused_cids:
            self._paused_cids.discard(cid)
            self._svc._resume()

    def begin_shutdown(self) -> None:
        self._discard = True

    close = begin_shutdown


class SocketInferenceClient:
    """Remote-side inference handle, one per pipeline stream: the same
    submit_async/wait/infer/pause/resume surface as
    ``PipeInferenceClient``, but over the shared ``SocketActorClient``
    ctrl link with seq-tagged at-most-once delivery."""

    def __init__(self, net: st.SocketActorClient, client_id: int):
        self._net = net
        self._id = client_id
        self._box = net.infer_box(client_id)
        self._seq = 0
        self._paused = False

    def bind_stop(self, stop_event: Any) -> None:
        pass                    # stop flows through the net client

    def submit_async(self, data: Any) -> Optional[Dict[str, Any]]:
        self._seq += 1
        buf = serde.encode_tree(data, meta={"client": self._id,
                                            "seq": self._seq,
                                            "t0": time.monotonic()})
        gen = self._net.ctrl_gen()
        if not self._net.ctrl_send(st.KIND_INFER_REQ, self._id, buf):
            return None
        return {"seq": self._seq, "buf": buf, "gen": gen}

    def wait(self, token: Optional[Dict[str, Any]]):
        from repro.distributed.inference import InferenceReply
        if token is None:
            return None
        while not self._net.stopped:
            payload = self._box.get(timeout=0.2)
            if payload is None:
                # nothing yet: redial if the link died (waiters are the
                # only ones who notice) — if the generation moved, the
                # request may be gone with the old link, so resubmit
                if self._net.ensure_ctrl() is None:
                    return None
                gen = self._net.ctrl_gen()
                if gen != token["gen"]:
                    token["gen"] = gen
                    if not self._net.ctrl_send(st.KIND_INFER_REQ,
                                               self._id, token["buf"]):
                        return None
                continue
            if payload == b"":
                return None                     # service shut down
            tree, meta = serde.decode_tree(payload, copy=True)
            if int(meta.get("seq", -1)) != token["seq"]:
                continue        # stale duplicate from a resubmit race
            return InferenceReply(tree["action"], tree["logprob"],
                                  (tree["lstm_h"], tree["lstm_c"]),
                                  int(meta["version"]))
        return None

    def infer(self, data: Any):
        return self.wait(self.submit_async(data))

    def pause(self) -> None:
        if not self._paused:
            self._paused = True
            self._net.ctrl_send(st.KIND_CTRL, self._id, st.CTRL_PAUSE)

    def resume(self) -> None:
        if self._paused:
            self._paused = False
            self._net.ctrl_send(st.KIND_CTRL, self._id, st.CTRL_RESUME)

    def close(self) -> None:
        self.resume()


# ---------------------------------------------------------------------------
# remote actor entry points


class _ComposedStop:
    """threading.Event-alike that also honours an external (possibly
    multiprocessing) stop event and the net client's learner-sent stop."""

    def __init__(self, net: st.SocketActorClient,
                 ext: Optional[Any] = None):
        self._net = net
        self._ext = ext
        self._local = threading.Event()

    def set(self) -> None:
        self._local.set()

    def is_set(self) -> bool:
        return self._local.is_set() or self._net.stopped or (
            self._ext is not None and self._ext.is_set())

    def wait(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else \
            time.monotonic() + timeout
        while True:
            if self.is_set():
                return True
            remaining = 0.1 if deadline is None else \
                min(0.1, deadline - time.monotonic())
            if remaining <= 0:
                return False
            if self._local.wait(remaining):
                return True


def remote_actor_main(address, stop_event: Optional[Any] = None,
                      *, backoff=(0.05, 1.0),
                      dial_timeout: float = 60.0) -> Optional[str]:
    """Run ONE remote actor against the learner at ``address``.

    Everything else — actor id, env, arch, impala config, seed, actor
    mode — arrives in the CONFIG handshake, so a remote machine needs
    only this function and a reachable address. ``address`` may be ANY
    learner of a group: a full learner refuses with the shard map and
    the client spills to one with a free slot. Returns None on a clean
    run, or the error traceback string (also reported to the learner
    over the ctrl link) on failure."""
    from repro.distributed import runner

    net = st.SocketActorClient(tuple(address), stop_event=stop_event,
                               backoff=backoff,
                               dial_timeout=dial_timeout)
    cfg = net.connect()
    if cfg is None:
        net.close(bye=False)
        if net.refused:
            return (f"refused by learner at {address[0]}:{address[1]}: "
                    "no free actor slot (every learner in the shard "
                    "map has live actors on all its slots)")
        if net.dial_failed:
            return (f"could not reach learner at "
                    f"{address[0]}:{address[1]} (dial timeout)")
        return None if net.stopped else "connect failed"
    stop = _ComposedStop(net, stop_event)
    if tuple(net.connected_addr) != tuple(address):
        # refused-with-shard-map spill landed us on another learner
        h, p = net.connected_addr
        print(f"actor {cfg.get('actor_id')}: spilled to learner "
              f"{h}:{p}", flush=True)
    try:
        runner._tune_child_scheduling(int(cfg["actor_id"]))
        arch_cfg = cfg_from_jsonable(cfg["arch"])
        icfg = cfg_from_jsonable(cfg["icfg"])
        common = dict(actor_id=int(cfg["actor_id"]),
                      env_name=cfg["env"], arch_cfg=arch_cfg, icfg=icfg,
                      num_envs=int(cfg["num_envs"]),
                      seed=int(cfg["seed"]), send_buf=net.send_traj,
                      stop=stop,
                      # negotiated at the handshake: check_codec already
                      # vetted it (an unknown codec refused the dial)
                      wire_codec=net.wire_codec)
        if cfg.get("mode", "unroll") == "inference":
            clients: List[SocketInferenceClient] = [
                SocketInferenceClient(
                    net, int(cfg["actor_id"]) *
                    int(cfg.get("infer_streams", 1)) + s)
                for s in range(int(cfg.get("infer_streams", 1)))]
            runner.run_serialized_inference_actor(
                infer_clients=clients, **common)
        else:
            runner.run_serialized_unroll_actor(
                pull_msg=net.pull_params, **common)
    except BaseException:
        text = traceback.format_exc()
        net.send_error(text)
        net.close(bye=True)
        return text
    net.close(bye=True)
    if net.dial_failed:
        return ("lost connection to learner at "
                f"{address[0]}:{address[1]} (dial timeout exhausted)")
    return None


def remote_actor_child(address, stop_event) -> None:
    """Picklable spawn target for loopback remote-actor children (the
    benchmark / single-box path); real remote machines call
    ``remote_actor_main`` (or ``launch.train --connect``) directly.

    Exits via ``os._exit``: a jax child that has run XLA computations
    can abort in C++ teardown ("terminate called without an active
    exception") when the interpreter exits with runtime threads still
    live — turning a perfectly clean run into a nonzero exit code at
    random. The error path already reported its traceback over the
    ctrl link; the exit code only needs to be honest."""
    import os
    err = remote_actor_main(tuple(address), stop_event)
    os._exit(0 if err is None else 1)
