"""Actor thread pool: N workers, each owning its own env batch, RNG
stream, and jitted unroll (paper §3's distributed actors, in-process).

Concurrency model: each worker's loop is (pull params) -> (jitted unroll)
-> (queue put). The unroll dispatch drops the GIL while XLA executes, so
workers genuinely overlap with each other and with the learner's
train_step on a multicore host — this is real decoupling, not simulated
lag. Each worker builds its own ``build_actor`` closure, so its jit cache,
env batch, and RNG stream are private; worker i derives its streams from
``fold_in(seed, i)`` so runs are reproducible per actor count.

Each produced trajectory is stamped with the parameter version it was
acted with (see ``paramstore``) plus its actor id, making per-trajectory
policy lag measurable at the learner.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

import jax

from repro.core import actor as actor_lib
from repro.distributed.paramstore import ParameterStore
from repro.distributed.tqueue import TrajectoryQueue

PyTree = Any


@dataclasses.dataclass
class TrajectoryItem:
    """What flows through the queue: the trajectory pytree plus the
    provenance needed for measured lag and per-actor accounting."""
    data: PyTree
    param_version: int
    actor_id: int
    produced_at: float


class ActorPool:
    def __init__(self, env, arch_cfg, icfg, num_envs: int, num_actors: int,
                 store: ParameterStore, queue: TrajectoryQueue,
                 seed: int = 0):
        if num_actors < 1:
            raise ValueError("num_actors must be >= 1")
        self.env = env
        self.num_envs = num_envs
        self.num_actors = num_actors
        self.store = store
        self.queue = queue
        self.seed = seed
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._builders = []
        for i in range(num_actors):
            # per-actor closure => per-actor jit cache and env batch
            self._builders.append(
                actor_lib.build_actor(env, arch_cfg, icfg, num_envs))
        self.frames = [0] * num_actors          # env frames produced
        self.trajectories = [0] * num_actors    # accepted into the queue
        self.rejected = [0] * num_actors        # lost to backpressure
        self.errors: List[BaseException] = []
        self._steady_t0: Optional[float] = None
        self._steady_frames0 = 0
        self._frames_per_traj = num_envs * icfg.unroll_length

    # ------------------------------------------------------------------

    def _run(self, idx: int) -> None:
        init_fn, unroll = self._builders[idx]
        base = jax.random.fold_in(jax.random.key(self.seed), idx)
        carry = init_fn(jax.random.fold_in(base, 1))
        try:
            while not self._stop.is_set():
                params, version = self.store.pull()
                carry, traj = unroll(params, carry)
                # materialise before enqueue: backpressure must reflect
                # finished work, not a ballooning async dispatch queue
                traj = jax.block_until_ready(traj)
                self.frames[idx] += self._frames_per_traj
                if self._steady_t0 is None:
                    # fps clock starts at the first finished trajectory
                    # (post-compile), mirroring the learner's steady-state
                    # window; benign race — near-identical timestamps
                    self._steady_t0 = time.monotonic()
                    self._steady_frames0 = sum(self.frames)
                item = TrajectoryItem(traj, version, idx, time.monotonic())
                attempt = 0
                while not self._stop.is_set():
                    if self.queue.put(item, timeout=0.1,
                                      count_stall=attempt == 0):
                        self.trajectories[idx] += 1
                        break
                    if self.queue.closed:
                        break                   # shutting down
                    if self.queue.policy == "drop_newest":
                        self.rejected[idx] += 1
                        break                   # genuine drop, move on
                    # block policy timed out: re-check stop flag and retry
                    attempt += 1
        except BaseException as e:  # surface in the learner thread
            self.errors.append(e)
            self.queue.close()

    # ------------------------------------------------------------------

    def start(self) -> None:
        for i in range(self.num_actors):
            t = threading.Thread(target=self._run, args=(i,),
                                 name=f"actor-{i}", daemon=True)
            self._threads.append(t)
            t.start()

    def stop(self) -> None:
        self._stop.set()

    def join(self, timeout: float = 30.0) -> None:
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(max(0.0, deadline - time.monotonic()))

    def raise_errors(self) -> None:
        if self.errors:
            raise RuntimeError("actor thread died") from self.errors[0]

    def stats(self) -> Dict[str, float]:
        total_frames = sum(self.frames)
        fps = 0.0
        if self._steady_t0 is not None:
            dt = time.monotonic() - self._steady_t0
            if dt > 0:
                fps = (total_frames - self._steady_frames0) / dt
        return {
            "num_actors": self.num_actors,
            "frames": total_frames,
            "trajectories": sum(self.trajectories),
            "rejected": sum(self.rejected),
            "actor_fps": fps,
            "frames_per_actor": list(self.frames),
        }
