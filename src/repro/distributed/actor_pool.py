"""Actor thread pool: N workers, each owning its own env batch, RNG
stream, and jitted unroll (paper §3's distributed actors, in-process).

Concurrency model: each worker's loop is (pull params) -> (jitted unroll)
-> (transport put). The unroll dispatch drops the GIL while XLA executes,
so workers genuinely overlap with each other and with the learner's
train_step on a multicore host — this is real decoupling, not simulated
lag. Each worker builds its own ``build_actor`` closure, so its jit
cache, env batch, and RNG stream are private; the loop body itself lives
in ``runner.run_actor_loop``, shared verbatim with the process backend.

The pool is written against the ``Transport`` interface. With the
in-process transport, items are live pytrees and put() outcomes carry
the accounting; with a serializing transport (``ShmTransport``), policy
decisions happen at the drain side, so acceptance/rejection is counted
through the transport's attribution hooks instead. Either way,
``stats()["rejected"]`` charges every lost trajectory — drop_newest
rejections *and* drop_oldest evictions — back to the actor that made it.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from repro.core import actor as actor_lib
from repro.distributed.paramstore import ParameterStore
from repro.distributed.runner import (run_actor_loop,
                                      run_inference_driver_loop)
from repro.distributed.serde import TrajectoryItem  # noqa: F401 (re-export)
from repro.distributed.supervise import Supervisor, fold_restart_seed
from repro.distributed.transport import Transport


class PoolAccounting:
    """The per-actor ledger both worker pools share: frames / accepted
    trajectories / losses, the steady-state fps clock, and the stats
    dict the runtime's telemetry embeds. Loss attribution can arrive
    from several threads at once (a producer counting its own rejection,
    the queue's eviction callback, a transport drain thread), so the
    ``rejected`` ledger is written under a lock.

    ``slot_base`` is the pool's first *global* actor slot id: a learner
    group shards the run's slots over its learners, and each pool owns
    the contiguous range [slot_base, slot_base + num_actors). Items
    carry global ids (that is what keeps an actor's RNG/env-seed stream
    independent of the sharding); the ledgers here are indexed locally,
    so attribution subtracts the base."""

    backend = "?"

    def _init_accounting(self, num_actors: int, frames_per_traj: int,
                         slot_base: int = 0) -> None:
        self.num_actors = num_actors
        self.slot_base = slot_base
        self.frames = [0] * num_actors          # env frames produced
        self.trajectories = [0] * num_actors    # accepted into the queue
        self.rejected = [0] * num_actors        # lost (rejected/evicted)
        self._acct_lock = threading.Lock()
        self._steady_t0: Optional[float] = None
        self._steady_frames0 = 0
        self._frames_per_traj = frames_per_traj

    def _note_accept(self, item: TrajectoryItem) -> None:
        self.trajectories[item.actor_id - self.slot_base] += 1

    def _note_loss(self, item: TrajectoryItem) -> None:
        with self._acct_lock:
            self.rejected[item.actor_id - self.slot_base] += 1

    def _note_frames(self, idx: int) -> None:
        self.frames[idx] += self._frames_per_traj
        if self._steady_t0 is None:
            # fps clock starts at the first finished trajectory
            # (post-compile), mirroring the learner's steady-state
            # window; benign race — near-identical timestamps
            self._steady_t0 = time.monotonic()
            self._steady_frames0 = sum(self.frames)

    def stats(self) -> Dict[str, float]:
        total_frames = sum(self.frames)
        fps = 0.0
        if self._steady_t0 is not None:
            dt = time.monotonic() - self._steady_t0
            if dt > 0:
                fps = (total_frames - self._steady_frames0) / dt
        return {
            "num_actors": self.num_actors,
            "slot_base": self.slot_base,
            "backend": self.backend,
            "frames": total_frames,
            "trajectories": sum(self.trajectories),
            "rejected": sum(self.rejected),
            "rejected_per_actor": list(self.rejected),
            "actor_fps": fps,
            "frames_per_actor": list(self.frames),
        }


class ActorPool(PoolAccounting):
    backend = "thread"

    def __init__(self, env, arch_cfg, icfg, num_envs: int, num_actors: int,
                 store: ParameterStore, queue: Transport, seed: int = 0,
                 service=None, slot_base: int = 0):
        """``service`` (an ``InferenceService``) switches the pool to
        inference mode: no per-actor policy or params — one *driver*
        thread multiplexes all logical actors' host-side env stepping
        against the shared batched forward (paper §3.1's dynamic
        batching); see ``_run_driver``.

        ``slot_base`` shifts this pool's actors onto the global slot
        range [slot_base, slot_base + num_actors) — workers derive
        their RNG stream from the *global* id, so a sharded learner
        group acts out exactly the per-actor randomness one learner
        owning all the slots would."""
        if num_actors < 1:
            raise ValueError("num_actors must be >= 1")
        self.env = env
        self.num_envs = num_envs
        self.store = store
        self.queue = queue
        self.seed = seed
        self.service = service
        self._arch_cfg = arch_cfg
        self._icfg = icfg
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._builders = []
        if service is None:
            for i in range(num_actors):
                # per-actor closure => per-actor jit cache and env batch
                self._builders.append(
                    actor_lib.build_actor(env, arch_cfg, icfg, num_envs))
        self.errors: List[BaseException] = []
        # supervised respawn (attach_supervisor): a dead worker thread
        # waits here for a restart grant instead of failing the run
        self._supervisor: Optional[Supervisor] = None
        self._dead: List[tuple] = []            # (idx, exc)
        self._respawns: Dict[str, tuple] = {}   # key -> (idx, decision)
        self._init_accounting(num_actors, num_envs * icfg.unroll_length,
                              slot_base)
        # attribution hooks: evictions always come back through the
        # transport; accept/reject only when the policy runs drain-side
        self._counts_at_drain = not queue.rejects_at_put
        if hasattr(queue, "on_drop"):
            queue.on_drop = self._note_loss
        if self._counts_at_drain:
            queue.on_item = self._note_accept
            queue.on_reject = self._note_loss

    # ------------------------------------------------------------------

    def _emit(self, idx: int, item: TrajectoryItem) -> bool:
        """Transport put with the policy-aware retry loop. True = keep
        producing; False = shut down."""
        attempt = 0
        while not self._stop.is_set():
            if self.queue.put(item, timeout=0.1, count_stall=attempt == 0):
                if not self._counts_at_drain:
                    self.trajectories[idx] += 1
                return True
            if self.queue.closed:
                return False                    # shutting down
            if self.queue.rejects_at_put and \
                    self.queue.policy == "drop_newest":
                with self._acct_lock:
                    self.rejected[idx] += 1
                return True                     # genuine drop, move on
            # block policy timed out (or wire momentarily full):
            # re-check stop flag and retry
            attempt += 1
        return False

    def _run(self, idx: int, epoch: int = 0) -> None:
        try:
            run_actor_loop(
                actor_id=self.slot_base + idx,
                builder=self._builders[idx],
                seed=fold_restart_seed(self.seed, epoch),
                pull_params=self.store.pull,
                emit=lambda item: self._emit(idx, item),
                should_stop=self._stop.is_set,
                on_unroll=lambda: self._note_frames(idx))
        except BaseException as e:  # surface in the learner thread
            self._note_death(idx, e)

    def _note_death(self, idx: int, exc: BaseException) -> None:
        """Unsupervised, a worker death fails the run (close the queue
        so the learner wakes and ``raise_errors`` fires). Supervised,
        it is parked for ``raise_errors`` to respawn — the queue stays
        open, the remaining workers keep producing."""
        if self._supervisor is not None and not self._stop.is_set():
            with self._acct_lock:
                self._dead.append((idx, exc))
        else:
            self.errors.append(exc)
            self.queue.close()

    def _run_driver(self, epoch: int = 0) -> None:
        """Inference mode: ONE thread multiplexes every logical actor —
        per-actor threads would only add GIL-serialized Event wake-ups
        to a loop whose heavy lifting (the batched policy forward)
        already happens in the shared service. Each logical actor keeps
        its thread-layout identity: own env batch, own
        fold_in(seed, actor_id) RNG stream, own trajectory stream."""
        try:
            run_inference_driver_loop(
                actor_ids=list(range(self.slot_base,
                                     self.slot_base + self.num_actors)),
                env=self.env, arch_cfg=self._arch_cfg, icfg=self._icfg,
                num_envs=self.num_envs,
                seed=fold_restart_seed(self.seed, epoch),
                service=self.service,
                emit=lambda aid, item: self._emit(aid - self.slot_base,
                                                  item),
                should_stop=self._stop.is_set,
                on_unroll=lambda aid: self._note_frames(
                    aid - self.slot_base))
        except BaseException as e:  # surface in the learner thread
            self._note_death(-1, e)

    # ------------------------------------------------------------------

    def attach_supervisor(self, supervisor: Supervisor) -> None:
        """Opt into supervised respawn: a worker thread that dies is
        respawned (same global slot, restart-epoch folded into its
        seed) on the next ``raise_errors`` call instead of failing the
        run — until the restart policy is exhausted, at which point
        ``raise_errors`` raises exactly as the unsupervised pool does."""
        self._supervisor = supervisor

    def _spawn(self, idx: int, epoch: int = 0) -> None:
        if idx < 0:
            t = threading.Thread(target=self._run_driver, args=(epoch,),
                                 name="inference-driver", daemon=True)
        else:
            t = threading.Thread(target=self._run, args=(idx, epoch),
                                 name=f"actor-{idx}", daemon=True)
        self._threads.append(t)
        t.start()

    def start(self) -> None:
        if self.service is not None:
            self._spawn(-1)
            return
        for i in range(self.num_actors):
            self._spawn(i)

    def stop(self) -> None:
        self._stop.set()
        if hasattr(self.queue, "begin_shutdown"):
            self.queue.begin_shutdown()     # serializing transport: keep
            # the wire draining (discard) while workers wind down

    def join(self, timeout: float = 30.0) -> None:
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(max(0.0, deadline - time.monotonic()))

    def raise_errors(self) -> None:
        if self._supervisor is not None:
            self._heal()
        if self.errors:
            raise RuntimeError("actor thread died") from self.errors[0]

    def _heal(self) -> None:
        """Ask the supervisor for restart grants for parked deaths and
        launch every respawn whose backoff has elapsed. Non-blocking:
        called from the learner loop every iteration, so backoff waits
        ride the loop instead of stalling training."""
        sup = self._supervisor
        with self._acct_lock:
            dead, self._dead = self._dead, []
        for idx, exc in dead:
            key = (f"actor-{self.slot_base + idx}" if idx >= 0
                   else f"driver-{self.slot_base}")
            decision = sup.record_death(key)
            if decision is None:    # budget exhausted: fail loudly
                self.errors.append(exc)
                self.queue.close()
                continue
            self._respawns[key] = (idx, decision)
        now = time.monotonic()
        due = [k for k, (_i, d) in self._respawns.items()
               if d.not_before <= now]
        for key in due:
            idx, decision = self._respawns.pop(key)
            self._spawn(idx, decision.epoch)
            sup.note_restarted(key)
