"""Actor *process* pools: spawn-based workers behind the same interface
as ``ActorPool`` (paper §3's actors on separate interpreters — acting no
longer competes with the learner for the GIL).

Two pools live here. ``ProcessActorPool`` wires its children over
multiprocessing primitives (shm transport + param/control pipes).
``SocketActorPool`` wires them over TCP (``SocketTransport``): children
— or entirely separate machines — dial the learner's listen address,
receive the whole run config in the handshake, and run the *same* loop
bodies; with ``spawn_local=False`` the pool spawns nothing and simply
waits for remote actors to connect.

Each worker process builds its own env batch, RNG stream, and jit cache
from picklable ingredients (env *name*, config dataclasses, seed) — no
live jax object crosses the boundary. Two channels connect it to the
parent:

  params     a duplex pipe to the parent's *param server* thread. The
             child asks "anything newer than version v?"; the server
             answers from ``ParameterStore.pull_serialized`` (encoded
             once per version, shared by all children).
  data       the ``ShmTransport`` wire. The child ships serde-encoded
             trajectory buffers; the parent's drain thread decodes and
             applies the backpressure policy.

Accounting happens entirely parent-side through the transport's
attribution hooks (accepted / rejected / evicted per actor id), so
``stats()`` has the same meaning as the thread pool's — with the caveat
that ``frames`` counts trajectories that *arrived* (in-flight unrolls in
a child are invisible until they land).

Shutdown: set the shared stop event; children exit their loop (their
wire puts and param pulls are timeout/poll-based); join with a deadline;
``terminate()`` stragglers so no orphan can outlive the run.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
import time
from multiprocessing import connection as mp_connection
from typing import List

from repro.distributed.actor_pool import PoolAccounting
from repro.distributed.paramstore import ParameterStore
from repro.distributed.runner import (inference_actor_main,
                                      process_actor_main)
from repro.distributed.serde import TrajectoryItem
from repro.distributed.supervise import (KillSafeEvent, Supervisor,
                                         fold_restart_seed)
from repro.distributed.transport import ShmTransport


class ProcessActorPool(PoolAccounting):
    backend = "process"

    def __init__(self, env_name: str, arch_cfg, icfg, num_envs: int,
                 num_actors: int, store: ParameterStore,
                 transport: ShmTransport, seed: int = 0, service=None,
                 infer_streams: int = 1, slot_base: int = 0):
        """``service`` (an ``InferenceService``) switches the children to
        inference mode: they hold no params and run no policy network —
        observation requests go up the service's process frontend wire,
        action replies come back over per-stream pipes
        (``infer_streams`` pipelined env half-batches per child), and
        the param pipe carries only error reports.

        ``slot_base`` shifts the children onto the global actor slot
        range [slot_base, slot_base + num_actors): each child derives
        its RNG stream (and its core-affinity pin) from the global id,
        so sharding the slots over a learner group changes neither the
        per-actor randomness nor which cores the children land on."""
        if num_actors < 1:
            raise ValueError("num_actors must be >= 1")
        if not isinstance(transport, ShmTransport):
            raise ValueError("ProcessActorPool requires a serializing "
                             "transport (--transport shm)")
        if not isinstance(env_name, str):
            raise ValueError("process actors rebuild the env by name; "
                             "pass an env name, not an Env object")
        self.env_name = env_name
        self.num_envs = num_envs
        self.store = store
        self.queue = transport
        self.seed = seed
        self._ctx = mp.get_context("spawn")
        # kill-safe: SIGKILLed children are this pool's normal case,
        # and a corpse holding mp.Event's lock would deadlock stop()
        self._stop = KillSafeEvent(self._ctx)
        self._procs: List[mp.process.BaseProcess] = []
        self._conns = []                        # parent ends of param pipes
        self._conn_lock = threading.Lock()      # respawns append live
        self.errors: List[str] = []             # child tracebacks
        # supervised respawn (attach_supervisor): a child that dies
        # WITHOUT reporting an error (SIGKILL, OOM) is respawned; a
        # reported traceback is a code bug and still raises
        self._supervisor: "Supervisor | None" = None
        self._live: dict = {}                   # local idx -> live process
        self._respawns: dict = {}               # key -> (idx, decision)
        # ``frames`` counts trajectories that *landed* parent-side: the
        # steady clock starts at the first arrival (post child startup +
        # compile), mirroring the thread pool's convention
        self._init_accounting(num_actors, num_envs * icfg.unroll_length,
                              slot_base)
        self._arch_cfg = arch_cfg
        self._icfg = icfg
        self.service = service
        self.infer_streams = infer_streams
        self._frontend = (service.process_frontend(
            self._ctx, num_actors * infer_streams)
            if service is not None else None)
        transport.on_item = self._note_arrival
        transport.on_reject = self._note_loss
        transport.on_drop = self._note_loss
        self._server = threading.Thread(target=self._serve_params,
                                        name="param-server", daemon=True)

    # ------------------------------------------------------------------
    # accounting (runs on the transport drain / param server threads)

    def _note_arrival(self, item: TrajectoryItem) -> None:
        self._note_accept(item)
        self._note_frames(item.actor_id - self.slot_base)

    # ------------------------------------------------------------------
    # param server: version-gated pub/sub over pipes

    def _serve_params(self) -> None:
        dead: set = set()
        while True:
            # re-read the conn list each pass: a supervised respawn
            # appends a fresh pipe mid-run and it must be served
            with self._conn_lock:
                conns = [c for c in self._conns if c not in dead]
            if not conns:
                if self._supervisor is None or self._stop.is_set():
                    break       # unsupervised: all children gone = done
                time.sleep(0.05)
                continue        # supervised: a respawn may repopulate
            ready = mp_connection.wait(conns, timeout=0.2)
            for conn in ready:
                try:
                    msg = conn.recv()
                except (EOFError, OSError):
                    dead.add(conn)
                    continue
                if msg[0] == "pull":
                    _, _actor_id, have_version = msg
                    if self._stop.is_set():
                        reply = ("stop",)
                    else:
                        fresh = self.store.pull_serialized(have_version)
                        reply = (("params", fresh[1], fresh[0])
                                 if fresh is not None else ("keep",))
                    try:
                        conn.send(reply)
                    except (OSError, BrokenPipeError):
                        dead.add(conn)
                elif msg[0] == "error":
                    self.errors.append(msg[2])
                    self.queue.close()
            if self._stop.is_set() and not any(
                    p.is_alive() for p in self._procs):
                break

    # ------------------------------------------------------------------

    def attach_supervisor(self, supervisor: Supervisor) -> None:
        """Opt into supervised respawn of silently-dead children (same
        global slot, restart-epoch folded into the seed). Children that
        *report* a traceback still raise — that is a code bug, not a
        fault. Inference-mode children are not respawned (their reply
        pipes are registered with the frontend once, at start)."""
        self._supervisor = supervisor

    def _spawn_child(self, i: int, epoch: int = 0):
        parent_conn, child_conn = self._ctx.Pipe()
        with self._conn_lock:
            self._conns.append(parent_conn)
        seed = fold_restart_seed(self.seed, epoch)
        clients = None
        if self._frontend is not None:
            # frontend client ids stay pool-local (the service is
            # per-learner); the child's actor id is global
            clients = [self._frontend.register(
                i * self.infer_streams + s)
                for s in range(self.infer_streams)]
            target, args = inference_actor_main, (
                self.slot_base + i, self.env_name, self._arch_cfg,
                self._icfg, self.num_envs, seed,
                self.queue.producer(), clients, child_conn,
                self._stop, self.queue.wire_codec)
        else:
            target, args = process_actor_main, (
                self.slot_base + i, self.env_name, self._arch_cfg,
                self._icfg, self.num_envs, seed,
                self.queue.producer(), child_conn, self._stop,
                self.queue.wire_codec)
        p = self._ctx.Process(target=target, args=args,
                              name=f"actor-proc-{i}", daemon=True)
        self._procs.append(p)
        self._live[i] = p
        p.start()
        child_conn.close()              # parent keeps only its end
        if clients is not None:
            for c in clients:
                c.close()               # ditto for reply recv-ends
        return p

    def start(self) -> None:
        for i in range(self.num_actors):
            self._spawn_child(i)
        if self._frontend is not None:
            self._frontend.start()
        self._server.start()

    def stop(self) -> None:
        self._stop.set()
        # keep the wires flowing (discarding) while children wind down,
        # so their queue feeders can always flush and no child ever
        # hangs at exit mid-write into a full pipe
        self.queue.begin_shutdown()
        if self._frontend is not None:
            self._frontend.begin_shutdown()

    def join(self, timeout: float = 30.0) -> None:
        deadline = time.monotonic() + timeout
        for p in self._procs:
            p.join(max(0.1, deadline - time.monotonic()))
        for p in self._procs:
            if p.is_alive():                # no orphans, ever
                p.terminate()
                p.join(timeout=5.0)
        if self._frontend is not None:
            self._frontend.close()          # children are gone: safe
        if self._server.is_alive():
            self._server.join(timeout=5.0)
        for conn in self._conns:
            try:
                conn.close()
            except OSError:
                pass

    def raise_errors(self) -> None:
        if self.errors:
            raise RuntimeError("actor process died:\n" + self.errors[0])
        if self._stop.is_set():
            return
        if self._supervisor is not None and self._frontend is None:
            self._heal()
            return
        # a child that crashed before it could report (import error,
        # OOM kill, ...) must not leave the learner polling forever
        for p in self._procs:
            if p.exitcode is not None and p.exitcode != 0:
                raise RuntimeError(
                    f"actor process {p.name} exited with code "
                    f"{p.exitcode} before reporting an error")

    def _heal(self) -> None:
        """Respawn silently-dead children under the restart policy.
        Non-blocking: called from the learner loop, so backoff waits
        ride the loop. A dead child reported no error (the errors
        branch above raised otherwise) — SIGKILL / preemption / OOM,
        the faults a fleet must absorb."""
        sup = self._supervisor
        for i, p in list(self._live.items()):
            if p.exitcode is None or p.exitcode == 0:
                continue
            del self._live[i]
            key = f"proc-{self.slot_base + i}"
            decision = sup.record_death(key)
            if decision is None:
                raise RuntimeError(
                    f"actor process {p.name} exited with code "
                    f"{p.exitcode}; restart budget exhausted")
            self._respawns[key] = (i, decision)
        now = time.monotonic()
        due = [k for k, (_i, d) in self._respawns.items()
               if d.not_before <= now]
        for key in due:
            i, decision = self._respawns.pop(key)
            self._spawn_child(i, decision.epoch)
            sup.note_restarted(key)


class SocketActorPool(PoolAccounting):
    """Remote actors over TCP behind the pool interface.

    The pool owns no channels of its own — it *configures* the
    ``SocketTransport`` it is given: the CONFIG-handshake payload (env
    name, arch/impala config, seed, mode) so a connecting machine needs
    nothing but the address, the param source
    (``ParameterStore.pull_serialized``, encoded once per version for
    all subscribers), the inference frontend when the run is in
    inference mode, and the per-actor attribution hooks.

    ``spawn_local=True`` (the default, and the benchmark / single-box
    path) spawns ``num_actors`` loopback children running
    ``netserve.remote_actor_child``; ``spawn_local=False`` is the real
    deployment shape — the learner listens, and ``num_actors`` remote
    machines run ``launch.train --connect host:port`` (or
    ``examples/train_remote.py actor``) whenever they come up.
    """

    backend = "remote"

    def __init__(self, env_name: str, arch_cfg, icfg, num_envs: int,
                 num_actors: int, store: ParameterStore,
                 transport, seed: int = 0, service=None,
                 infer_streams: int = 1, spawn_local: bool = True,
                 slot_base: int = 0):
        from repro.distributed import netserve
        from repro.distributed.socket_transport import SocketTransport

        if num_actors < 1:
            raise ValueError("num_actors must be >= 1")
        if not isinstance(transport, SocketTransport):
            raise ValueError("SocketActorPool requires a SocketTransport "
                             "(--transport socket)")
        if not isinstance(env_name, str):
            raise ValueError("remote actors rebuild the env by name; "
                             "pass an env name, not an Env object")
        self.env_name = env_name
        self.num_envs = num_envs
        self.store = store
        self.queue = transport
        self.seed = seed
        self.spawn_local = spawn_local
        self._ctx = mp.get_context("spawn")
        self._stop = KillSafeEvent(self._ctx)   # see ProcessActorPool
        self._procs: List[mp.process.BaseProcess] = []
        self.errors: List[str] = []             # remote tracebacks
        self._supervisor: "Supervisor | None" = None
        self._live: dict = {}                   # local idx -> live process
        self._respawns: dict = {}               # key -> (idx, decision)
        self._init_accounting(num_actors, num_envs * icfg.unroll_length,
                              slot_base)
        self.service = service
        self.infer_streams = infer_streams
        mode = "inference" if service is not None else "unroll"
        cfg = netserve.build_actor_config(
            env_name=env_name, arch_cfg=arch_cfg, icfg=icfg,
            num_envs=num_envs, seed=seed, mode=mode,
            infer_streams=infer_streams)
        transport.max_actors = num_actors
        transport.config_extra = lambda actor_id: cfg
        transport.param_source = store.pull_serialized
        transport.on_item = self._note_arrival
        transport.on_reject = self._note_loss
        transport.on_drop = self._note_loss
        transport.on_error = self.errors.append
        self._frontend = (netserve.SocketInferenceFrontend(
            service, transport, streams=infer_streams)
            if service is not None else None)

    # accounting runs on the transport's connection threads
    def _note_arrival(self, item: TrajectoryItem) -> None:
        self._note_accept(item)
        self._note_frames(item.actor_id - self.slot_base)

    # ------------------------------------------------------------------

    def attach_supervisor(self, supervisor: Supervisor) -> None:
        """Opt into supervised respawn of locally-spawned children that
        die without reporting an error. The respawned child redials the
        learner; ``_bind``'s reclaim hands it the dead slot (ownership
        transfer bumps the slot's restart epoch, which the CONFIG
        handshake folds into the seed). Truly remote actors are an
        operator's to relaunch — the reaper only frees their lease."""
        self._supervisor = supervisor

    def _spawn_child(self, i: int):
        from repro.distributed.netserve import remote_actor_child
        p = self._ctx.Process(
            target=remote_actor_child,
            args=(tuple(self.queue.address), self._stop),
            name=f"actor-remote-{i}", daemon=True)
        self._procs.append(p)
        self._live[i] = p
        p.start()
        return p

    def start(self) -> None:
        if not self.spawn_local:
            return                      # remote machines dial in
        for i in range(self.num_actors):
            self._spawn_child(i)

    def stop(self) -> None:
        self._stop.set()
        if self._frontend is not None:
            self._frontend.begin_shutdown()
        # flips the transport to discard (data conns keep draining so a
        # child mid-send can always finish its frame) and broadcasts the
        # stop control frame to every connected actor
        self.queue.begin_shutdown()

    def join(self, timeout: float = 30.0) -> None:
        deadline = time.monotonic() + timeout
        for p in self._procs:
            p.join(max(0.1, deadline - time.monotonic()))
        for p in self._procs:
            if p.is_alive():                # no orphans, ever
                p.terminate()
                p.join(timeout=5.0)

    def raise_errors(self) -> None:
        if self.errors:
            raise RuntimeError("remote actor died:\n" + self.errors[0])
        if self._stop.is_set():
            return
        if self._supervisor is not None and self.spawn_local:
            self._heal()
            return
        for p in self._procs:
            if p.exitcode is not None and p.exitcode != 0:
                raise RuntimeError(
                    f"actor process {p.name} exited with code "
                    f"{p.exitcode} before reporting an error")

    def _heal(self) -> None:
        """Mirror of ``ProcessActorPool._heal`` for loopback socket
        children: respawn a silently-dead child under the restart
        policy; the redial reclaims its slot via the nonce lease."""
        sup = self._supervisor
        for i, p in list(self._live.items()):
            if p.exitcode is None or p.exitcode == 0:
                continue
            del self._live[i]
            key = f"remote-{self.slot_base + i}"
            decision = sup.record_death(key)
            if decision is None:
                raise RuntimeError(
                    f"actor process {p.name} exited with code "
                    f"{p.exitcode}; restart budget exhausted")
            self._respawns[key] = (i, decision)
        now = time.monotonic()
        due = [k for k, (_i, d) in self._respawns.items()
               if d.not_before <= now]
        for key in due:
            i, decision = self._respawns.pop(key)
            self._spawn_child(i)
            sup.note_restarted(key)
