"""TCP socket transport: the serde buffers over a real network (the
paper's cross-machine actor->learner queue, IMPALA §3 Fig. 1).

``SocketTransport`` is the learner side: it listens, accepts remote
actors, and implements the uniform put/get/backpressure/counters
``Transport`` API — per-connection drain threads read length-prefixed,
CRC-checked frames (``serde.pack_frame``), decode trajectory items, and
apply the configured backpressure policy in the local
``TrajectoryQueue``, exactly where ``ShmTransport``'s drain thread does.
``SocketActorClient`` is the remote side: a machine that knows only the
learner's address dials in, receives its actor id and run configuration
in the handshake, and then needs nothing but env stepping —
trajectories go up, versioned parameters (and, in inference mode,
actions) come down.

Every actor holds TWO connections, mirroring the shm layout's separate
data wire and param pipe:

  data   carries only trajectory frames. Under the ``block`` policy the
         learner-side drain stalls in the local queue, stops reading,
         and TCP flow control pushes the stall back into the actor's
         ``send`` — real end-to-end backpressure over the network.
  ctrl   carries everything that must stay responsive while data is
         backpressured: the config handshake, parameter pulls,
         inference requests/replies, pause/resume hints, error reports,
         and the shutdown handshake.

Failure discipline (what the chaos suite pins down):

  * a frame that ends early (peer killed mid-write, link severed) is
    detected by the length prefix and **never delivered** — it is
    counted as a torn tail, and the connection is dropped;
  * a CRC or magic mismatch means the byte stream is desynchronised;
    there is no way to re-find frame boundaries, so the connection is
    dropped and counted, never "resynced";
  * the client reconnects with exponential backoff. A frame whose send
    did not complete is resent on the fresh connection (a partial frame
    is invisible to the learner, so the resend cannot duplicate);
    a frame fully handed to a dying kernel socket is the one
    trajectory a sever can lose;
  * shutdown reuses the discard protocol: the learner flips to discard
    but keeps draining, sends a ``stop`` control frame, and each actor
    answers ``bye`` before closing — so no shutdown ever tears a frame.

Deliberately no jax import: remote actor processes import this module
before deciding to build a policy at all.
"""
from __future__ import annotations

import collections
import json
import random
import socket
import struct
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.distributed import serde
from repro.distributed.serde import TrajectoryItem
from repro.distributed.tqueue import POLICIES, TrajectoryQueue

# frame kinds multiplexed over one connection (serde.pack_frame kind)
KIND_HELLO = 1       # actor -> learner: {"role": "ctrl"|"data", "actor_id"}
KIND_CONFIG = 2      # learner -> actor: the run config json (ctrl only)
KIND_TRAJ = 3        # actor -> learner: serde-encoded TrajectoryItem
KIND_PARAM_REQ = 4   # actor -> learner: int64 have_version
KIND_PARAM = 5       # learner -> actor: int64 version + encoded params
KIND_PARAM_KEEP = 6  # learner -> actor: nothing newer than have_version
KIND_INFER_REQ = 7   # actor -> learner: serde obs request (stream=client)
KIND_INFER_REP = 8   # learner -> actor: serde reply (stream=client)
KIND_CTRL = 9        # both ways: stop / bye / pause / resume
KIND_ERROR = 10      # actor -> learner: traceback text
# learner <-> learner (the gradient exchange rides the same CRC frame
# format and torn-tail discipline as everything else on the wire)
KIND_GRAD = 11       # spoke -> hub: serde grad leaves (stream=learner)
KIND_GRAD_MEAN = 12  # hub -> spoke: reduced mean for one round
KIND_HEARTBEAT = 13  # actor -> learner: liveness beacon (ctrl only)

CTRL_STOP = b"stop"
CTRL_BYE = b"bye"
CTRL_REFUSED = b"refused"   # no free actor slot: distinct from run-end
CTRL_PAUSE = b"pause"
CTRL_RESUME = b"resume"

_I64 = struct.Struct("<q")

Address = Tuple[str, int]


class Disconnected(Exception):
    """The peer is gone (EOF/reset) or a stop was requested mid-read.

    ``partial`` is how many bytes of an in-flight frame had arrived —
    nonzero with ``stopped=False`` means the peer died mid-frame (a
    torn tail, counted but never delivered)."""

    def __init__(self, partial: int = 0, stopped: bool = False):
        super().__init__(f"disconnected (partial={partial}, "
                         f"stopped={stopped})")
        self.partial = partial
        self.stopped = stopped


def _recv_exactly(sock: socket.socket, n: int,
                  stop: Optional[Callable[[], bool]]) -> bytes:
    """Blocking read of exactly ``n`` bytes; the 0.2s socket timeout is
    the stop-poll cadence, not a deadline — a slow sender mid-frame just
    keeps accumulating."""
    buf = bytearray()
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except socket.timeout:
            if stop is not None and stop():
                raise Disconnected(len(buf), stopped=True)
            continue
        except (OSError, ValueError):
            raise Disconnected(len(buf))
        if not chunk:
            raise Disconnected(len(buf))
        buf += chunk
    return bytes(buf)


class FrameChannel:
    """One TCP connection speaking serde frames: a write-locked ``send``
    that either puts a *whole* frame on the wire or marks the channel
    dead (a partial write would tear the stream for every later frame),
    and a single-reader ``recv`` returning complete, CRC-verified
    frames."""

    # grace for finishing an in-flight frame once stop is requested: the
    # learner drains in discard mode during shutdown, so a healthy
    # connection completes in microseconds — this bounds a dead one
    STOP_FLUSH_GRACE_S = 5.0

    def __init__(self, sock: socket.socket):
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:  # pragma: no cover — not a TCP socket (tests)
            pass
        sock.settimeout(0.2)
        self._sock = sock
        self._wlock = threading.Lock()
        self.dead = False
        self.bytes_in = 0
        self.bytes_out = 0
        self.frames_in = 0
        self.frames_out = 0

    def send(self, kind: int, stream_id: int = 0, payload: bytes = b"",
             stop: Optional[Callable[[], bool]] = None) -> bool:
        """Write one whole frame. False = nothing (or only a torn
        prefix, invisible to the receiver as data) made it out and the
        channel is dead or stopping — safe to resend on a fresh
        connection."""
        frame = memoryview(serde.pack_frame(kind, stream_id, payload))
        with self._wlock:
            if self.dead:
                return False
            off = 0
            stop_deadline = None
            while off < len(frame):
                if stop is not None and stop():
                    if off == 0:
                        return False
                    # mid-frame: finishing is the only non-tearing exit
                    now = time.monotonic()
                    if stop_deadline is None:
                        stop_deadline = now + self.STOP_FLUSH_GRACE_S
                    elif now > stop_deadline:
                        self.dead = True
                        return False
                try:
                    off += self._sock.send(frame[off:])
                except socket.timeout:
                    continue
                except (OSError, ValueError):
                    self.dead = True
                    return False
            self.bytes_out += len(frame)
            self.frames_out += 1
            return True

    def recv(self, stop: Optional[Callable[[], bool]] = None
             ) -> Tuple[int, int, bytes]:
        """One complete frame: (kind, stream_id, payload). Raises
        ``Disconnected`` on EOF/stop (``partial`` > 0 = torn tail) and
        ``serde.SerdeError`` on magic/CRC corruption (stream is
        desynchronised: drop the connection)."""
        hdr = _recv_exactly(self._sock, serde.FRAME_HEADER_SIZE, stop)
        kind, stream_id, length, crc = serde.parse_frame_header(hdr)
        if length:
            try:
                payload = _recv_exactly(self._sock, length, stop)
            except Disconnected as d:
                raise Disconnected(serde.FRAME_HEADER_SIZE + d.partial,
                                   d.stopped)
        else:
            payload = b""
        serde.verify_frame_payload(kind, stream_id, payload, crc)
        self.bytes_in += serde.FRAME_HEADER_SIZE + length
        self.frames_in += 1
        return kind, stream_id, payload

    def close(self) -> None:
        self.dead = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


class _ActorSlot:
    """Per-remote-actor server-side state and telemetry."""

    __slots__ = ("actor_id", "ctrl", "data", "binds", "owner_nonce",
                 "frames", "bytes", "torn_tails", "reconnects", "losses",
                 "wait_sum", "wait_n", "last_seen", "lease_reaps",
                 "epoch")

    def __init__(self, actor_id: int):
        self.actor_id = actor_id
        self.ctrl: Optional[FrameChannel] = None
        self.data: Optional[FrameChannel] = None
        self.binds: Dict[str, int] = {}     # role -> connection count
        self.owner_nonce: Optional[str] = None
        self.frames = 0          # trajectory frames accepted
        self.bytes = 0
        self.torn_tails = 0
        self.reconnects = 0
        self.losses = 0          # rejected/evicted, attributed here
        self.wait_sum = 0.0      # recv -> accepted-into-queue latency
        self.wait_n = 0
        self.last_seen = time.monotonic()   # liveness stamp (any frame)
        self.lease_reaps = 0     # deadline-expired leases on this slot
        self.epoch = 0           # ownership transfers (restart epoch)


class SocketTransport:
    """Learner-side TCP transport: accept loop + per-connection drain
    threads feeding the in-proc policy queue.

    The policy (block / drop_oldest / drop_newest) runs here, at the
    drain side — like ``ShmTransport``, ``rejects_at_put`` is False and
    loss attribution arrives through the hooks:

      on_item(item)     decoded item accepted into the local queue
      on_reject(item)   decoded item rejected by drop_newest
      on_drop(item)     queued item evicted by drop_oldest (inner hook)

    Integration points (all optional, set before actors connect):

      config_extra      fn(actor_id) -> dict merged into the CONFIG
                        handshake payload (the pool ships env/arch/run
                        config through this). The handshake WAITS for
                        this to be bound — the accept loop starts with
                        the constructor, and an external actor dialing
                        the instant the port opens must not receive a
                        config-less handshake
      param_source      fn(have_version) -> None | (buf, version); the
                        pool binds ``ParameterStore.pull_serialized``
      handlers[kind]    fn(chan, stream_id, payload) for frame kinds
                        the transport doesn't own (inference requests)
      ctrl_handler      fn(stream_id, payload) for pause/resume hints
      on_error          fn(text) for remote error reports (also kept
                        in ``self.errors``)
    """

    rejects_at_put = False

    # Cap the kernel buffering of actor->learner trajectory bytes. TCP
    # would happily buffer megabytes per connection — several whole
    # trajectories sitting OUTSIDE the bounded queue, invisible to the
    # block policy. That silently deepens the pipeline (measured: +10-20
    # versions of policy lag on a loopback catch run) and raises how
    # much a severed link can lose. With ~256KB the flow control
    # engages at roughly trajectory granularity: backpressure reaches
    # the actor within a trajectory or two, like the shm wire.
    DATA_BUF_BYTES = 1 << 18
    # the byte cap exists to hold ~1-2 trajectory FRAMES in the kernel;
    # a quantizing codec shrinks frames (bf16/int8 float leaves + the
    # deflate pass over the rest measures 6-12x on the bench envs), so
    # the same byte budget would silently hold 8+ frames of invisible
    # pipeline and policy lag climbs right back up (measured: ~10 -> ~29
    # mean lag on loopback catch with bf16 under the fp32-sized cap).
    # Scale the cap with the codec so flow control stays at trajectory
    # granularity; the floor keeps the window sane for tiny payloads.
    QUANT_BUF_DIV = 8
    MIN_DATA_BUF = 1 << 14

    def __init__(self, capacity: int = 8, policy: str = "block",
                 listen: Address = ("127.0.0.1", 0),
                 max_actors: Optional[int] = None,
                 data_buf_bytes: int = DATA_BUF_BYTES,
                 slot_base: int = 0, registry=None,
                 wire_codec: str = serde.DEFAULT_CODEC,
                 heartbeat_timeout_s: Optional[float] = None,
                 elastic: bool = False):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got "
                             f"{policy!r}")
        self.capacity = capacity
        self.policy = policy
        # liveness: when set, the CONFIG handshake asks actors to
        # heartbeat on ctrl (timeout/3 cadence) and a reaper thread
        # expires the slot lease of any actor silent past the deadline —
        # its slot becomes reclaimable without waiting for a full house.
        # None (default) keeps the pre-supervision behavior: leases only
        # move when a relaunched actor claims a dead slot.
        self.heartbeat_timeout_s = heartbeat_timeout_s
        # elastic membership: with elastic=True a dialer finding every
        # slot taken by a LIVE actor gets a NEW slot past the
        # ``max_actors`` ceiling instead of a refusal — actors may join
        # the fleet at any time. ``on_slot_grown`` fires (outside the
        # slot lock) so pool accounting can grow with it.
        self.elastic = elastic
        self.on_slot_grown: Optional[Callable[[int], None]] = None
        self.supervisor = None          # optional supervise.Supervisor
        # the run's wire codec: announced in the CONFIG handshake so
        # every actor encodes the way this learner expects (a peer that
        # doesn't speak it refuses loudly at connect, never mid-run)
        self.wire_codec = serde.check_codec(wire_codec)
        self.max_actors = max_actors
        if data_buf_bytes and self.wire_codec != "none":
            data_buf_bytes = max(data_buf_bytes // self.QUANT_BUF_DIV,
                                 self.MIN_DATA_BUF)
        self.data_buf_bytes = data_buf_bytes
        # shard-aware slot assignment: this learner hands out global
        # actor ids in [slot_base, slot_base + max_actors). peer_addrs
        # (set by the pool/group before actors connect) is the shard
        # map — every learner's listen address — shipped in the CONFIG
        # handshake and in refusals, so an external actor that dialed a
        # full learner spills to one with a free slot instead of dying.
        self.slot_base = slot_base
        self.peer_addrs: Optional[List[Address]] = None
        self._inner = TrajectoryQueue(capacity, policy, registry=registry)
        self.registry = self._inner.registry
        self.on_item: Optional[Callable[[TrajectoryItem], None]] = None
        self.on_reject: Optional[Callable[[TrajectoryItem], None]] = None
        self.config_extra: Optional[Callable[[int],
                                             Dict[str, Any]]] = None
        self.param_source: Optional[
            Callable[[int], Optional[Tuple[bytes, int]]]] = None
        self.handlers: Dict[int, Callable[[FrameChannel, int, bytes],
                                          None]] = {}
        self.ctrl_handler: Optional[Callable[[int, bytes], None]] = None
        self.on_ctrl_gone: Optional[Callable[[int], None]] = None
        self.on_error: Optional[Callable[[str], None]] = None

        self._stop = threading.Event()
        self._discard = False
        self._closed = False
        self._close_lock = threading.Lock()
        self._lock = threading.Lock()           # slots / counters
        self._slots: Dict[int, _ActorSlot] = {}
        self._slot_by_nonce: Dict[str, _ActorSlot] = {}
        self._next_id = slot_base
        self._threads: List[threading.Thread] = []

        # telemetry (conn-thread writes under self._lock; snapshot()
        # reads). Stored as registry instruments so the live /metrics
        # endpoint and the end-of-run snapshot read the same storage;
        # the read-only properties below keep `t.frames_in` etc. working
        self._c_frames_in = self.registry.counter("socket.frames_in")
        self._c_bytes_in = self.registry.counter("socket.bytes_in")
        # trajectory compression accounting: payload bytes as they rode
        # the wire vs the raw leaf bytes they decoded to — the
        # bytes/frame numerator the bandwidth-diet benchmarks assert on
        self._c_traj_wire = self.registry.counter("socket.traj_wire_bytes")
        self._c_traj_raw = self.registry.counter("socket.traj_raw_bytes")
        self._c_torn_tails = self.registry.counter("socket.torn_tails")
        self._c_reconnects = self.registry.counter("socket.reconnects")
        self._c_discarded = self.registry.counter("socket.discarded")
        self._c_heartbeats = self.registry.counter("socket.heartbeats")
        self._c_lease_reaps = self.registry.counter("socket.lease_reaps")
        self.decode_errors: List[str] = []      # CRC/magic/serde failures
        self.errors: List[str] = []             # remote actor tracebacks
        self._t0: Optional[float] = None        # first-frame clock

        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        if data_buf_bytes:
            # must be set on the LISTENER (inherited by accepted
            # sockets) to take effect before the window opens
            try:
                self._lsock.setsockopt(socket.SOL_SOCKET,
                                       socket.SO_RCVBUF, data_buf_bytes)
            except OSError:  # pragma: no cover
                pass
        self._lsock.bind(tuple(listen))
        self._lsock.listen(64)
        self._lsock.settimeout(0.2)
        self.address: Address = self._lsock.getsockname()[:2]
        self._acceptor = threading.Thread(target=self._accept_loop,
                                          name="socket-accept",
                                          daemon=True)
        self._acceptor.start()
        self._reaper: Optional[threading.Thread] = None
        if heartbeat_timeout_s is not None:
            self._reaper = threading.Thread(target=self._reap_loop,
                                            name="socket-reaper",
                                            daemon=True)
            self._reaper.start()

    # ------------------------------------------------------------------
    # eviction attribution passes straight through to the local queue

    @property
    def on_drop(self):
        return self._inner.on_drop

    @on_drop.setter
    def on_drop(self, fn):
        self._inner.on_drop = fn

    # counter views (the registry instruments are the storage)

    @property
    def frames_in(self) -> int:
        return self._c_frames_in.value

    @property
    def bytes_in(self) -> int:
        return self._c_bytes_in.value

    @property
    def torn_tails(self) -> int:
        return self._c_torn_tails.value

    @property
    def reconnects(self) -> int:
        return self._c_reconnects.value

    @property
    def discarded(self) -> int:
        return self._c_discarded.value

    # ------------------------------------------------------------------
    # accept + handshake

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, _peer = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(target=self._conn_entry, args=(sock,),
                                 name="socket-conn", daemon=True)
            with self._lock:
                # prune reaped connections: a long run with flaky
                # actors must not accumulate dead Thread objects
                self._threads = [x for x in self._threads
                                 if x.is_alive()]
                self._threads.append(t)
            t.start()

    def _reap_loop(self) -> None:
        """Deadline-based liveness: expire the slot lease of any actor
        silent past ``heartbeat_timeout_s``. The lease (nonce ownership)
        is what a reap revokes — the slot itself stays, with its
        accounting, for the next claimant; a reaped actor that was
        merely wedged finds its redial refused (stale nonce) and exits
        visibly instead of fighting the claimant for the slot."""
        timeout = self.heartbeat_timeout_s
        poll = min(1.0, timeout / 4.0)
        while not self._stop.wait(poll):
            if self._discard:
                continue
            now = time.monotonic()
            reaped: List[int] = []
            with self._lock:
                for slot in self._slots.values():
                    live = ((slot.ctrl is not None and not slot.ctrl.dead)
                            or (slot.data is not None
                                and not slot.data.dead))
                    held = slot.owner_nonce is not None
                    if not (live or held):
                        continue            # nothing to reap
                    if now - slot.last_seen <= timeout:
                        continue
                    for k in [k for k, v in self._slot_by_nonce.items()
                              if v is slot]:
                        del self._slot_by_nonce[k]
                    slot.owner_nonce = None
                    slot.lease_reaps += 1
                    self._c_lease_reaps.inc()
                    reaped.append(slot.actor_id)
                chans = [c for s in self._slots.values()
                         if s.actor_id in reaped
                         for c in (s.ctrl, s.data) if c is not None]
            for chan in chans:      # close outside the slot lock
                chan.close()
            for actor_id in reaped:
                if self.supervisor is not None:
                    self.supervisor.record_lease_reap(f"slot-{actor_id}")

    def _conn_entry(self, sock: socket.socket) -> None:
        chan = FrameChannel(sock)
        deadline = time.monotonic() + 5.0
        try:
            kind, _stream, payload = chan.recv(
                stop=lambda: self._stop.is_set() or
                time.monotonic() > deadline)
        except (Disconnected, serde.SerdeError):
            chan.close()
            return
        if kind != KIND_HELLO:
            chan.close()
            return
        try:
            hello = json.loads(payload.decode("utf-8")) if payload else {}
        except ValueError:
            chan.close()
            return
        role = hello.get("role", "data")
        actor_id = int(hello.get("actor_id", -1))
        slot = self._bind(role, actor_id, chan,
                          nonce=hello.get("nonce"))
        if slot is None:
            # full house: refuse, distinctly from a run-end stop, so
            # the surplus actor exits NONZERO and an operator notices
            # instead of seeing "clean". With a shard map bound, the
            # refusal carries the OTHER learners' addresses so the
            # actor spills to one with a free slot instead of dying —
            # how an external machine dialing any one learner of a
            # group finds the learner that owns its slot.
            payload = CTRL_REFUSED
            spill = [list(a) for a in (self.peer_addrs or [])
                     if tuple(a) != tuple(self.address)]
            if spill:
                payload += b" " + json.dumps(spill).encode("utf-8")
            chan.send(KIND_CTRL, 0, payload)
            chan.close()
            return
        try:
            if role == "ctrl":
                gate = time.monotonic() + 10.0
                while self.config_extra is None and \
                        not self._stop.is_set() and \
                        time.monotonic() < gate:
                    time.sleep(0.02)
                extra = self.config_extra
                cfg = {"actor_id": slot.actor_id,
                       "data_buf": self.data_buf_bytes,
                       "wire_codec": self.wire_codec}
                if self.heartbeat_timeout_s is not None:
                    # ask the actor to beacon at a third of the reap
                    # deadline: two missed beats of slack before the
                    # lease expires
                    cfg["heartbeat_s"] = self.heartbeat_timeout_s / 3.0
                if self.peer_addrs is not None:
                    # the group's shard map: every learner's listen
                    # address, so the remote machine knows the whole
                    # topology from one handshake
                    cfg["shard_map"] = [list(a) for a in self.peer_addrs]
                if extra is not None:
                    cfg.update(extra(slot.actor_id))
                if slot.epoch and "seed" in cfg:
                    # restart-epoch seed folding for a slot whose
                    # previous owner died: the run config is shared,
                    # so the fold happens per-slot at handshake time
                    from repro.distributed.supervise import \
                        fold_restart_seed
                    cfg["seed"] = fold_restart_seed(int(cfg["seed"]),
                                                    slot.epoch)
                chan.send(KIND_CONFIG, 0,
                          json.dumps(cfg).encode("utf-8"),
                          stop=self._stop.is_set)
                if self._discard:       # late joiner during shutdown
                    chan.send(KIND_CTRL, 0, CTRL_STOP)
                self._ctrl_loop(slot, chan)
            else:
                self._data_loop(slot, chan)
        finally:
            chan.close()
            with self._lock:
                if getattr(slot, role, None) is chan:
                    setattr(slot, role, None)
            if role == "ctrl" and self.on_ctrl_gone is not None:
                # tell the serving layer this actor can no longer
                # submit or be replied to (until it reconnects): stale
                # pause hints and client counts must not outlive the
                # connection that made them
                try:
                    self.on_ctrl_gone(slot.actor_id)
                except Exception:   # a hook bug must not kill accept
                    pass

    def _bind(self, role: str, actor_id: int, chan: FrameChannel,
              nonce: Optional[str] = None) -> Optional[_ActorSlot]:
        if role not in ("ctrl", "data"):
            return None
        grew = False
        with self._lock:
            next_before = self._next_id
            if actor_id < 0:
                if role != "ctrl":
                    return None         # data conns must name their actor
                # idempotent assignment: a client whose handshake was
                # severed before CONFIG landed retries with the same
                # nonce and gets its already-allocated slot back — a
                # flaky link must not leak slots until the run refuses
                # its own actors
                slot = (self._slot_by_nonce.get(nonce)
                        if nonce else None)
                if slot is None and self.max_actors is not None and \
                        self._next_id >= self.slot_base + self.max_actors:
                    # all ids handed out: RECLAIM a slot with no live
                    # connections — a crashed external actor relaunched
                    # by an operator must get its capacity back, not a
                    # refusal (losses/frames remain attributed to the
                    # slot, which is the point: the slot IS the actor).
                    # Ownership moves to the claimant's nonce, so if
                    # the old actor was merely in reconnect backoff its
                    # later redial is refused outright instead of the
                    # two fighting over one slot forever.
                    for s in self._slots.values():
                        if (s.ctrl is None or s.ctrl.dead) and \
                                (s.data is None or s.data.dead):
                            slot = s
                            for k in [k for k, v in
                                      self._slot_by_nonce.items()
                                      if v is slot]:
                                del self._slot_by_nonce[k]
                            slot.owner_nonce = nonce
                            if nonce:
                                self._slot_by_nonce[nonce] = slot
                            # a NEW actor took over the slot: bump the
                            # restart epoch so the CONFIG handshake can
                            # fold it into the seed — the successor
                            # must not replay its predecessor's stream
                            slot.epoch += 1
                            break
                    if slot is None and not self.elastic:
                        return None     # every slot has a live actor
                    # elastic membership: every slot has a live actor,
                    # so GROW — hand out a fresh global id past the
                    # ceiling rather than turning a willing machine away
                if slot is None:
                    actor_id = self._next_id
                    self._next_id += 1
                    slot = self._slots[actor_id] = _ActorSlot(actor_id)
                    slot.owner_nonce = nonce
                    if nonce:
                        self._slot_by_nonce[nonce] = slot
                actor_id = slot.actor_id
            else:
                slot = self._slots.get(actor_id)
                if slot is None:
                    if actor_id < self.slot_base or (
                            self.max_actors is not None and actor_id >=
                            self.slot_base + self.max_actors):
                        return None     # not this learner's shard
                    slot = self._slots[actor_id] = _ActorSlot(actor_id)
                    slot.owner_nonce = nonce
                    self._next_id = max(self._next_id, actor_id + 1)
                elif slot.owner_nonce and nonce and \
                        nonce != slot.owner_nonce:
                    # the slot was reclaimed by a relaunched actor while
                    # this one was away: its lease is gone, refuse
                    return None
            # a rebind of a previously-bound role is a reconnect whether
            # or not the dead connection's thread was reaped yet
            if slot.binds.get(role, 0):
                slot.reconnects += 1
                self._c_reconnects.inc()
            slot.binds[role] = slot.binds.get(role, 0) + 1
            old = getattr(slot, role)
            if old is not None:
                old.close()
            setattr(slot, role, chan)
            slot.last_seen = time.monotonic()
            grew = (self.max_actors is not None
                    and self._next_id > next_before
                    and slot.actor_id >=
                    self.slot_base + self.max_actors)
        if grew and self.on_slot_grown is not None:
            try:
                self.on_slot_grown(slot.actor_id)
            except Exception:       # accounting growth must not kill accept
                pass
        return slot

    # ------------------------------------------------------------------
    # connection drains

    def _data_loop(self, slot: _ActorSlot, chan: FrameChannel) -> None:
        while not self._stop.is_set():
            try:
                kind, _stream, payload = chan.recv(stop=self._stop.is_set)
            except Disconnected as d:
                if d.partial and not d.stopped:
                    with self._lock:
                        slot.torn_tails += 1
                        self._c_torn_tails.inc()
                return
            except serde.SerdeError as e:       # desynced: drop the conn
                self.decode_errors.append(repr(e))
                return
            with self._lock:
                self._c_bytes_in.inc(len(payload) + serde.FRAME_HEADER_SIZE)
                slot.last_seen = time.monotonic()
            if kind == KIND_CTRL:
                if payload == CTRL_BYE:         # clean shutdown handshake
                    return
                continue
            if kind != KIND_TRAJ:
                continue
            with self._lock:
                # trajectory frames only: frames_in is the numerator of
                # the throughput telemetry, and a bye must not open the
                # rate clock
                self._c_frames_in.inc()
                if self._t0 is None:
                    self._t0 = time.monotonic()
            if self._discard:
                with self._lock:
                    self._c_discarded.inc()
                continue
            t_recv = time.monotonic()
            try:
                item = serde.decode_item(payload)
            except Exception as e:              # corrupt *payload* spec
                self.decode_errors.append(repr(e))
                continue
            with self._lock:
                self._c_traj_wire.inc(len(payload))
                self._c_traj_raw.inc(serde.tree_nbytes(item.data))
            self._policy_put(slot, item, t_recv, len(payload))

    def _policy_put(self, slot: _ActorSlot, item: TrajectoryItem,
                    t_recv: float, nbytes: int) -> None:
        """The same drain discipline as ``ShmTransport``: block-policy
        stalls HERE (so TCP flow control reaches the producer), the
        drop policies decide immediately — and a put that fails because
        the queue closed under us is shutdown, never attributed as a
        policy rejection."""
        while not self._stop.is_set() and not self._discard:
            if self._inner.put(item, timeout=0.1):
                with self._lock:
                    slot.frames += 1
                    slot.bytes += nbytes
                    slot.wait_sum += time.monotonic() - t_recv
                    slot.wait_n += 1
                if self.on_item is not None:
                    self.on_item(item)
                return
            if self._inner.closed or self._discard:
                return                          # shutdown, not a policy
            if self._inner.policy == "drop_newest":
                with self._lock:
                    slot.losses += 1
                if self.on_reject is not None:
                    self.on_reject(item)
                return                          # genuine policy rejection
            # block policy: local queue full, learner slow — stall here
            # so this connection stops reading and backpressure travels

    def _ctrl_loop(self, slot: _ActorSlot, chan: FrameChannel) -> None:
        while not self._stop.is_set():
            try:
                kind, stream, payload = chan.recv(stop=self._stop.is_set)
            except Disconnected:
                return
            except serde.SerdeError as e:
                self.decode_errors.append(repr(e))
                return
            with self._lock:
                # any ctrl traffic proves liveness; the explicit
                # heartbeat only matters when the actor is otherwise
                # idle (e.g. data link stalled under backpressure)
                slot.last_seen = time.monotonic()
                if kind == KIND_HEARTBEAT:
                    self._c_heartbeats.inc()
            if kind == KIND_HEARTBEAT:
                pass
            elif kind == KIND_PARAM_REQ:
                self._serve_params(chan, payload)
            elif kind == KIND_CTRL:
                if payload == CTRL_BYE:
                    return
                if payload in (CTRL_PAUSE, CTRL_RESUME) and \
                        self.ctrl_handler is not None:
                    self.ctrl_handler(stream, payload)
            elif kind == KIND_ERROR:
                text = payload.decode("utf-8", "replace")
                self.errors.append(text)
                if self.on_error is not None:
                    self.on_error(text)
            else:
                handler = self.handlers.get(kind)
                if handler is not None:
                    handler(chan, stream, payload)

    def _serve_params(self, chan: FrameChannel, payload: bytes) -> None:
        if len(payload) != _I64.size:
            return
        (have_version,) = _I64.unpack(payload)
        src = self.param_source
        fresh = src(have_version) if src is not None and \
            not self._discard else None
        if fresh is None:
            chan.send(KIND_PARAM_KEEP, 0, b"", stop=self._stop.is_set)
        else:
            buf, version = fresh
            chan.send(KIND_PARAM, 0, _I64.pack(version) + buf,
                      stop=self._stop.is_set)

    # ------------------------------------------------------------------
    # Transport API (learner side)

    def put(self, item: TrajectoryItem, timeout: Optional[float] = None,
            count_stall: bool = True) -> bool:
        """Local (learner-process) put, straight into the policy queue —
        remote producers use ``SocketActorClient``; this exists for the
        Transport contract and learner-internal requeues."""
        return self._inner.put(item, timeout=timeout,
                               count_stall=count_stall)

    def get(self, timeout: Optional[float] = None):
        return self._inner.get(timeout)

    def get_nowait(self):
        return self._inner.get_nowait()

    def requeue_front(self, item: TrajectoryItem) -> None:
        self._inner.requeue_front(item)

    # ------------------------------------------------------------------
    # lifecycle

    def begin_shutdown(self) -> None:
        """Flip to discard and tell every actor to stop: data conns keep
        draining (an actor mid-send can always finish its frame — no
        torn frames at shutdown), the local queue closes so learner-side
        consumers drain what's left, and the ``stop`` control frame
        sends remote actors into their exit path. Call before joining
        actor processes; call ``close`` after."""
        self._discard = True
        self._inner.close()
        with self._lock:
            chans = [s.ctrl for s in self._slots.values()
                     if s.ctrl is not None]
        # bounded PER CHANNEL: a wedged peer must not stall shutdown,
        # and must not consume the budget of the healthy actors behind
        # it in this loop (the frame is tiny; a live link takes it
        # instantly)
        for chan in chans:
            deadline = time.monotonic() + 2.0
            chan.send(KIND_CTRL, 0, CTRL_STOP,
                      stop=lambda d=deadline: time.monotonic() > d)

    def close(self) -> None:
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
        self.begin_shutdown()
        self._stop.set()
        try:
            self._lsock.close()
        except OSError:
            pass
        with self._lock:
            chans = [c for s in self._slots.values()
                     for c in (s.ctrl, s.data) if c is not None]
            threads = list(self._threads)
        for chan in chans:
            chan.close()
        self._acceptor.join(timeout=5.0)
        if self._reaper is not None:
            self._reaper.join(timeout=5.0)
        for t in threads:
            t.join(timeout=5.0)

    @property
    def closed(self) -> bool:
        return self._stop.is_set()

    def __len__(self) -> int:
        return len(self._inner)

    # ------------------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        snap = self._inner.snapshot()
        now = time.monotonic()
        with self._lock:
            dt = (now - self._t0) if self._t0 is not None else 0.0
            per_actor = {
                s.actor_id: {
                    "frames": s.frames,
                    "bytes": s.bytes,
                    "losses": s.losses,
                    "torn_tails": s.torn_tails,
                    "reconnects": s.reconnects,
                    "queue_wait_ms_mean": (1e3 * s.wait_sum / s.wait_n
                                           if s.wait_n else 0.0),
                    "connected": (s.data is not None and not s.data.dead)
                    or (s.ctrl is not None and not s.ctrl.dead),
                    "last_seen_age_s": now - s.last_seen,
                    "lease_reaps": s.lease_reaps,
                }
                for s in self._slots.values()
            }
            frames = self.frames_in
            snap.update({
                "transport": "socket",
                "listen": list(self.address),
                "actors_seen": len(self._slots),
                "frames_in": frames,
                "bytes_in": self.bytes_in,
                "wire_codec": self.wire_codec,
                "traj_wire_bytes": self._c_traj_wire.value,
                "traj_raw_bytes": self._c_traj_raw.value,
                "bytes_per_frame": (self._c_traj_wire.value / frames
                                    if frames else 0.0),
                "wire_compression": (
                    self._c_traj_raw.value / self._c_traj_wire.value
                    if self._c_traj_wire.value else 1.0),
                "bytes_per_sec": (self.bytes_in / dt if dt > 0 else 0.0),
                "frames_per_sec": (self.frames_in / dt if dt > 0 else 0.0),
                "reconnects": self.reconnects,
                "torn_tails": self.torn_tails,
                "discarded": self.discarded,
                "heartbeats": self._c_heartbeats.value,
                "lease_reaps": self._c_lease_reaps.value,
                "elastic": self.elastic,
                "decode_errors": len(self.decode_errors),
                "remote_errors": len(self.errors),
                "per_actor": per_actor,
            })
        return snap


# SocketTransport satisfies the Transport interface structurally (it is
# defined in its own module so ``transport.py`` stays import-light);
# make isinstance() agree.
from repro.distributed.transport import Transport  # noqa: E402

Transport.register(SocketTransport)


class _InferReplyBox:
    """Per-client mailbox for inference replies arriving on the ctrl
    reader thread; ``wake`` unblocks waiters on disconnect so they can
    notice the generation change and resubmit."""

    def __init__(self):
        self._cond = threading.Condition()
        self._replies: collections.deque = collections.deque()

    def put(self, payload: bytes) -> None:
        with self._cond:
            self._replies.append(payload)
            self._cond.notify_all()

    def wake(self) -> None:
        with self._cond:
            self._cond.notify_all()

    def get(self, timeout: float) -> Optional[bytes]:
        with self._cond:
            if not self._replies:
                self._cond.wait(timeout)
            if not self._replies:
                return None
            return self._replies.popleft()


class SocketActorClient:
    """Remote-actor side: dial the learner, learn who you are (the
    CONFIG handshake carries the actor id and the whole run config),
    then ship trajectories and pull params. Reconnects with exponential
    backoff; safe-resends frames whose write did not complete (the
    learner never sees a partial frame as data, so a resend cannot
    duplicate).

    ``stop_event`` (optional, any object with ``is_set``) composes an
    external shutdown signal with the learner's ``stop`` control frame;
    ``stopped`` reflects both."""

    def __init__(self, address: Address, *,
                 stop_event: Optional[Any] = None,
                 backoff: Tuple[float, float] = (0.05, 1.0),
                 dial_timeout: float = 60.0,
                 heartbeat_s: Optional[float] = None):
        import uuid
        self._addr = tuple(address)
        self._tried_addrs: set = set()  # learners that refused us
        self._backoff = backoff
        self._dial_timeout = dial_timeout
        self._ext_stop = stop_event
        self._stopped = threading.Event()
        # idempotent-handshake token: a severed HELLO/CONFIG exchange
        # retried with the same nonce reuses the slot it already got
        self._nonce = uuid.uuid4().hex
        # per-client decorrelated backoff jitter: a fleet of actors
        # reconnecting to a restarted learner must not dial in phase
        self._rng = random.Random(self._nonce)
        self.heartbeat_s = heartbeat_s  # None: learner's CONFIG decides
        self._hb_thread: Optional[threading.Thread] = None
        self.dial_failed = False        # dial_timeout exhausted mid-run
        self.refused = False            # learner had no free actor slot
        self._chans: Dict[str, Optional[FrameChannel]] = {"ctrl": None,
                                                          "data": None}
        self._gen = {"ctrl": 0, "data": 0}
        self._dial_lock = threading.Lock()
        import queue as stdlib_queue
        self._param_q: "stdlib_queue.Queue" = stdlib_queue.Queue()
        self._infer_boxes: Dict[int, _InferReplyBox] = {}
        self._boxes_lock = threading.Lock()
        self.config: Dict[str, Any] = {}
        self.actor_id = -1
        self.wire_codec = serde.DEFAULT_CODEC   # set by the handshake
        self.reconnects = 0
        self.trajs_sent = 0

    # ------------------------------------------------------------------

    @property
    def stopped(self) -> bool:
        return self._stopped.is_set() or (
            self._ext_stop is not None and self._ext_stop.is_set())

    @property
    def connected_addr(self) -> Address:
        """The learner this client actually ended up on — differs from
        the dialed address after a refused-with-shard-map spill."""
        return tuple(self._addr)

    def _stop_check(self) -> bool:
        return self.stopped

    def _jittered(self, delay: float) -> float:
        """Decorrelate a backoff sleep: uniform in [delay/2, delay],
        capped by the backoff ceiling. Half-jitter keeps retries fast
        while spreading a fleet's redials across the window."""
        cap = self._backoff[1]
        return min(self._rng.uniform(delay * 0.5, delay), cap)

    def connect(self) -> Optional[Dict[str, Any]]:
        """Dial ctrl (handshake: HELLO up, CONFIG down) then data.
        Returns the config dict, or None if stopped/refused."""
        if self._channel("ctrl") is None:
            return None
        if self._channel("data") is None:
            return None
        interval = self.heartbeat_s or self.config.get("heartbeat_s")
        if interval and self._hb_thread is None:
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop, args=(float(interval),),
                name="socket-heartbeat", daemon=True)
            self._hb_thread.start()
        return self.config

    def _heartbeat_loop(self, interval: float) -> None:
        """Liveness beacon: a KIND_HEARTBEAT on ctrl every ``interval``
        seconds so the learner-side reaper can tell a live-but-quiet
        actor (long episode, backpressured data link) from a dead one.
        Best-effort: a dead ctrl link is redialed by ``_channel``; a
        failed send is simply retried next tick."""
        while not self.stopped:
            if self._stopped.wait(interval):
                break
            try:
                chan = self._channel("ctrl")
                if chan is not None and not chan.dead:
                    chan.send(KIND_HEARTBEAT, 0, b"",
                              stop=self._stop_check)
            except Exception:   # never let liveness kill the actor
                pass

    # ------------------------------------------------------------------
    # connection management

    def _channel(self, role: str) -> Optional[FrameChannel]:
        chan = self._chans[role]
        if chan is not None and not chan.dead:
            return chan
        with self._dial_lock:
            chan = self._chans[role]            # raced a redialer?
            if chan is not None and not chan.dead:
                return chan
            if self.stopped:
                return None
            if chan is not None:
                chan.close()
                self.reconnects += 1
            fresh = self._dial(role)
            self._chans[role] = fresh
            if fresh is not None:
                self._gen[role] += 1
                if role == "ctrl":
                    t = threading.Thread(
                        target=self._ctrl_reader, args=(fresh,),
                        name="socket-ctrl-reader", daemon=True)
                    t.start()
            return fresh

    def _dial(self, role: str) -> Optional[FrameChannel]:
        delay = self._backoff[0]
        deadline = time.monotonic() + self._dial_timeout
        while not self.stopped and time.monotonic() < deadline:
            try:
                sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
                if role == "data":
                    # mirror the learner's receive cap (arrives in the
                    # CONFIG handshake): trajectory bytes the kernel
                    # would buffer are policy-invisible pipeline depth
                    buf = int(self.config.get("data_buf", 0) or 0)
                    if buf:
                        try:
                            sock.setsockopt(socket.SOL_SOCKET,
                                            socket.SO_SNDBUF, buf)
                        except OSError:  # pragma: no cover
                            pass
                sock.settimeout(1.0)
                sock.connect(self._addr)
            except OSError:
                try:
                    sock.close()
                except OSError:
                    pass
                time.sleep(min(self._jittered(delay),
                               max(0.0, deadline - time.monotonic())))
                delay = min(delay * 2, self._backoff[1])
                continue
            chan = FrameChannel(sock)
            hello = json.dumps({"role": role,
                                "actor_id": self.actor_id,
                                "nonce": self._nonce}).encode()
            if not chan.send(KIND_HELLO, 0, hello,
                             stop=self._stop_check):
                chan.close()
                continue
            if role == "data":
                return chan
            # ctrl: the handshake's reply is the run config
            try:
                kind, _stream, payload = chan.recv(stop=self._stop_check)
            except (Disconnected, serde.SerdeError):
                chan.close()
                time.sleep(self._jittered(delay))
                delay = min(delay * 2, self._backoff[1])
                continue
            if kind == KIND_CTRL and (
                    payload == CTRL_STOP or
                    payload.startswith(CTRL_REFUSED)):
                chan.close()
                if payload.startswith(CTRL_REFUSED):
                    # refused-with-shard-map: this learner's shard is
                    # full, but the refusal names its peers — spill to
                    # the first one we have not tried yet (how an
                    # external actor that dialed any one learner of a
                    # group finds the learner with a free slot)
                    # a wildcard bind host (0.0.0.0/::/"") in the map
                    # is not dialable from here — the group's learners
                    # share one machine (port+k), so substitute the
                    # host we actually reached this learner on
                    spill = [((self._addr[0], p)
                              if h in ("0.0.0.0", "::", "") else (h, p))
                             for h, p in self._spill_addrs(payload)]
                    self._tried_addrs.add(tuple(self._addr))
                    nxt = next((a for a in spill
                                if a not in self._tried_addrs), None)
                    if nxt is not None:
                        self._addr = nxt
                        delay = self._backoff[0]
                        continue
                    self.refused = True
                else:
                    self.refused = False
                self._stopped.set()             # run closing / no slot
                return None
            if kind != KIND_CONFIG:
                chan.close()
                continue
            cfg = json.loads(payload.decode("utf-8"))
            # codec negotiation: the learner announced how this fleet
            # encodes the wire. A codec we don't speak must refuse NOW
            # with a distinct error — encoding frames the learner can't
            # decode (or vice versa) would surface as garbage decodes
            # or silent corruption deep in training instead
            try:
                self.wire_codec = serde.check_codec(
                    cfg.get("wire_codec", serde.DEFAULT_CODEC))
            except serde.CodecMismatchError:
                chan.send(KIND_CTRL, 0, CTRL_BYE, stop=self._stop_check)
                chan.close()
                self._stopped.set()
                raise
            self.actor_id = int(cfg.get("actor_id", self.actor_id))
            self.config = cfg
            return chan
        if not self.stopped:
            # dial_timeout exhausted on a live run: wedging silently in
            # a retry loop (or acting on frozen params) would hide the
            # outage — fail the actor visibly instead. The learner sees
            # a nonzero child exit (spawned) or an operator sees the
            # returned error (external machine).
            self.dial_failed = True
            self._stopped.set()
        return None

    @staticmethod
    def _spill_addrs(payload: bytes) -> List[Tuple[str, int]]:
        """Parse the optional shard-map suffix of a refusal payload
        (``b"refused [[host, port], ...]"``); [] when absent/garbled."""
        rest = payload[len(CTRL_REFUSED):].strip()
        if not rest:
            return []
        try:
            addrs = json.loads(rest.decode("utf-8"))
            return [(str(h), int(p)) for h, p in addrs]
        except (ValueError, TypeError):
            return []

    def _ctrl_reader(self, chan: FrameChannel) -> None:
        while not self.stopped:
            try:
                kind, stream, payload = chan.recv(stop=self._stop_check)
            except (Disconnected, serde.SerdeError):
                chan.dead = True
                break
            if kind == KIND_PARAM:
                (version,) = _I64.unpack(payload[:_I64.size])
                self._param_q.put(("params", int(version),
                                   payload[_I64.size:]))
            elif kind == KIND_PARAM_KEEP:
                self._param_q.put(("keep",))
            elif kind == KIND_INFER_REP:
                with self._boxes_lock:
                    box = self._infer_boxes.get(stream)
                if box is not None:
                    box.put(payload)
            elif kind == KIND_CTRL and payload == CTRL_STOP:
                self._stopped.set()
                break
            # KIND_CONFIG re-sent on reconnect: already held, ignore
        with self._boxes_lock:
            boxes = list(self._infer_boxes.values())
        for box in boxes:
            box.wake()

    # ------------------------------------------------------------------
    # actor-facing API

    def send_traj(self, buf: bytes) -> bool:
        """Ship one encoded trajectory; blocks under learner
        backpressure (TCP flow control), reconnects on a dead link,
        False only when stopping."""
        while not self.stopped:
            chan = self._channel("data")
            if chan is None:
                return False
            if chan.send(KIND_TRAJ, 0, buf, stop=self._stop_check):
                self.trajs_sent += 1
                return True
            # dead mid-frame: the learner discarded the torn tail, so
            # resending the whole frame on a fresh link is duplicate-free
        return False

    def pull_params(self, have_version: int,
                    timeout: float = 2.0) -> Optional[Tuple]:
        """Version-gated pull over ctrl: ("params", version, buf) |
        ("keep",) | None on shutdown. Retries across reconnects; the
        reply wait doubles per retry (capped) so a large param frame on
        a slow link is not re-requested while it is still streaming —
        each redundant request would queue ANOTHER full-size reply
        behind the one in flight."""
        import queue as stdlib_queue
        wait = timeout
        while not self.stopped:
            try:                # drop replies from a timed-out attempt
                while True:
                    self._param_q.get_nowait()
            except stdlib_queue.Empty:
                pass
            chan = self._channel("ctrl")
            if chan is None:
                return None
            if not chan.send(KIND_PARAM_REQ, 0,
                             _I64.pack(int(have_version)),
                             stop=self._stop_check):
                continue
            try:
                return self._param_q.get(timeout=wait)
            except stdlib_queue.Empty:
                wait = min(wait * 2, 30.0)
                continue        # link died or learner slow: retry
        return None

    def ctrl_send(self, kind: int, stream_id: int = 0,
                  payload: bytes = b"") -> bool:
        while not self.stopped:
            chan = self._channel("ctrl")
            if chan is None:
                return False
            if chan.send(kind, stream_id, payload,
                         stop=self._stop_check):
                return True
        return False

    def ctrl_gen(self) -> int:
        return self._gen["ctrl"]

    def ensure_ctrl(self) -> Optional[FrameChannel]:
        """Redial the ctrl link if it died — the liveness hook for
        pollers (an inference client waiting on a reply must be the one
        to notice the dead link, or nobody bumps the generation)."""
        return self._channel("ctrl")

    def infer_box(self, client_id: int) -> _InferReplyBox:
        with self._boxes_lock:
            box = self._infer_boxes.get(client_id)
            if box is None:
                box = self._infer_boxes[client_id] = _InferReplyBox()
            return box

    def send_error(self, text: str) -> None:
        try:
            self.ctrl_send(KIND_ERROR, 0, text.encode("utf-8"))
        except Exception:
            pass

    def close(self, bye: bool = True) -> None:
        """Clean exit: say ``bye`` on both links (so the learner knows
        the EOF that follows is a handshake, not a torn frame), then
        close and stop."""
        for role in ("data", "ctrl"):
            chan = self._chans[role]
            if chan is not None:
                if bye and not chan.dead:
                    chan.send(KIND_CTRL, 0, CTRL_BYE,
                              stop=self._stop_check)
                chan.close()
        self._stopped.set()
        if self._hb_thread is not None:
            self._hb_thread.join(timeout=2.0)
        with self._boxes_lock:
            boxes = list(self._infer_boxes.values())
        for box in boxes:
            box.wake()
