"""The learner worker, extracted from the ``run_async_training``
monolith so one implementation serves both the single-learner runtime
and the multi-learner ``LearnerGroup`` (paper §3's *several learners,
each owning a shard of actors*).

A ``Learner`` owns exactly the four things the old loop hard-coded:

  batch collection   drain ONE ``Transport`` with dynamic batching
                     (power-of-two buckets, oldest-first requeue of
                     overflow, optional linger deadline) into per-bucket
                     ping-ponged host staging buffers;
  train step         the donated fused ``train_step`` when it trains
                     alone; a split ``grad_step`` / ``apply_step``
                     pair when a ``GradientExchange`` sits between the
                     backward pass and the optimizer (data-parallel
                     learners apply the *exchanged mean*, so replicas
                     stay bit-identical); or the donated ``shard_map``
                     SPMD step when the exchange is in-XLA
                     (``CollectiveExchange``): batch sharded over a
                     ``('data',)`` mesh, params/opt replicated, the
                     gradient mean a fused ``lax.pmean`` — the
                     N-learner-group update without N processes or a
                     single TCP frame;
  publish            every update lands in the learner's own
                     ``ParameterStore`` — self-versioned when alone,
                     at the exchange-delegated version when grouped
                     (one designated publisher numbers the rounds, so
                     every actor in the group sees a single monotonic
                     version stream);
  telemetry          the same snapshot keys the runtime always
                     reported (updates, fps, batch/lag histograms,
                     queue, actors, inference), plus ``learner_id`` /
                     ``exchange`` sections only when grouped.

Per-learner randomness is ``fold_in(key(seed), learner_id)`` —
``self.key``, which seeds the grouped inference service's sampling
stream — while parameter *initialization* stays at the raw
``key(seed)`` on every learner: data-parallel replicas must start
identical, and ``--learners 1`` must bit-match the single-learner run.

Deliberately no jax import at module level: ``LearnerGroup`` worker
processes import this module (like the transports) before paying the
jax import, and the import-guard test pins that edge.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.metrics import EpisodeTracker
from repro.core import replay as replay_lib
from repro.distributed.paramstore import ParameterStore
from repro.distributed.serde import TrajectoryItem
from repro.obs.metrics import Registry

PyTree = Any


class MultiTracker:
    """Episode-return accounting across actor-local env batches.

    ``slot_base`` maps *global* actor slot ids (what a sharded pool
    stamps into trajectories) onto this learner's local tracker list —
    learner k of a group owns slots [base, base+n) and sees only those.
    Completion times are recorded (CLOCK_MONOTONIC, comparable across
    processes on one box) so a group can merge the per-learner streams
    back into one chronological return history."""

    def __init__(self, num_actors: int, num_envs: int,
                 slot_base: int = 0):
        self.trackers = [EpisodeTracker(num_envs) for _ in range(num_actors)]
        self.slot_base = slot_base
        self._merged: List[float] = []
        self._merged_at: List[float] = []

    def update(self, actor_id: int, rewards, dones) -> None:
        t = self.trackers[actor_id - self.slot_base]
        before = len(t.completed)
        t.update(np.asarray(rewards), np.asarray(dones))
        # merge in consumption order so mean_return's last-n window is
        # chronological, not actor-grouped
        fresh = t.completed[before:]
        if fresh:
            now = time.monotonic()
            self._merged.extend(fresh)
            self._merged_at.extend([now] * len(fresh))

    @property
    def completed(self) -> List[float]:
        return list(self._merged)

    @property
    def completed_timed(self) -> List[Tuple[float, float]]:
        """(monotonic completion time, return) pairs, consumption
        order — what a group merge sorts on."""
        return list(zip(self._merged_at, self._merged))

    def mean_return(self, last_n: int = 100) -> float:
        if not self._merged:
            return float("nan")
        return float(np.mean(self._merged[-last_n:]))


def _buckets(max_batch_trajs: int) -> List[int]:
    """Power-of-two stack sizes <= max, descending (compile-count bound)."""
    out, b = [], 1
    while b <= max_batch_trajs:
        out.append(b)
        b *= 2
    return out[::-1]


def _collect_batch(queue, buckets: List[int], first: TrajectoryItem,
                   linger_s: float = 0.0,
                   max_items: Optional[int] = None) -> List[TrajectoryItem]:
    """Starting from ``first`` (already popped), drain the queue up to
    the largest bucket, then trim to the largest power-of-two that
    fits — requeueing the overflow *at the front, newest first*, so the
    queue keeps oldest-first order and the next batch starts with the
    trajectories this one could not stack.

    ``linger_s`` is the learner-side flush deadline (the mirror of the
    inference service's): rather than greedily training on whatever is
    queued, wait up to this long for the bucket to fill. A starved
    learner taking singleton batches pays the update's fixed cost per
    trajectory — and on a shared host, those extra updates steal the
    very cores the actors need to refill the queue. The deadline bounds
    the staleness this adds; a full bucket never waits.

    ``max_items`` (replay path) caps fresh collection below the top
    bucket — the learner tops the batch up with replayed trajectories,
    so it deliberately drains fewer online ones per update."""
    items = [first]
    cap = buckets[0] if max_items is None else min(max_items, buckets[0])
    deadline = (time.monotonic() + linger_s) if linger_s > 0 else None
    while len(items) < cap:
        nxt = queue.get_nowait()
        if nxt is None:
            if deadline is None:
                break
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            nxt = queue.get(timeout=remaining)
            if nxt is None:
                break
        items.append(nxt)
    k = next(b for b in buckets if b <= len(items))
    for extra in reversed(items[k:]):
        queue.requeue_front(extra)
    return items[:k]


def _device_put_copies() -> bool:
    """Probe whether ``jax.device_put`` of a host buffer COPIES on this
    backend. The CPU backend zero-copy *aliases* 64-byte-aligned numpy
    buffers (measured on jax 0.4.37, ~half of all allocations): the
    returned "device" array IS the host memory, so a staging buffer
    that produced one can never be rewritten while any consumer might
    still read the batch. Probed on a deterministically 64-aligned
    view so the answer doesn't depend on allocator luck."""
    import jax

    raw = np.zeros(1024 + 16, np.float32)
    off = (-raw.ctypes.data) % 64 // raw.itemsize
    aligned = raw[off:off + 1024]
    dev = jax.device_put(aligned)
    jax.block_until_ready(dev)
    aligned[0] = 1.0
    return float(np.asarray(dev)[0]) == 0.0


class _HostStager:
    """Per-(bucket, structure) host staging buffers for the learner's
    consume path.

    Serialized transports deliver numpy (often read-only view) leaves;
    stacking ``k`` trajectories with ``np.concatenate`` allocates one
    intermediate per leaf per update. Instead each leaf is written in
    place into a staging buffer and the whole tree moves with one
    ``device_put``. Buffer lifetime depends on what ``device_put``
    does, probed once:

      copies (accelerators)   two preallocated sets per bucket,
          **ping-ponged**, and before a set is *re*-written the batch
          it produced two updates ago is ``block_until_ready``-ed — the
          ping-pong alone only pipelines the async H2D transfer, it is
          not a completion guarantee (by reuse time the transfer has
          long finished, so the block is effectively free).
      aliases (CPU backend)   the "transfer" is free but the batch IS
          the staging memory, with no event to wait on for its
          consumers — so buffers are freshly allocated per stack and
          never reused (same copy count as the concatenate path, still
          a single device_put for the whole tree).

    ``mesh`` (SPMD learner mode) switches to *sharded* staging: one
    host buffer set per mesh device, each leaf's rows written straight
    into its shard's buffer, one ``device_put`` per shard, and the
    pieces assembled into global arrays under an explicit
    ``NamedSharding`` — the batch lands pre-sharded on the ``('data',)``
    axis with no dispatch-time re-slicing. A row count the mesh cannot
    split falls back to a single buffer replicated explicitly
    (mirroring ``sharding/rules.py``'s divisibility fallback).
    """

    def __init__(self, mesh=None):
        self._slots: Dict[Any, list] = {}
        self._reuse = _device_put_copies()
        self.last_device_put_s = 0.0    # phase-timing probe, per stack
        self._mesh = mesh
        self._n = int(mesh.devices.size) if mesh is not None else 1

    def stack(self, items: List[TrajectoryItem]) -> Optional[PyTree]:
        """Staged stack of >=2 same-shaped numpy trajectories; None if
        the items are not uniform host trees (caller falls back)."""
        import jax

        datas = [it.data for it in items]
        leaves0, treedef = jax.tree.flatten(datas[0])
        if not all(isinstance(x, np.ndarray) for x in leaves0):
            return None
        shapes = tuple((x.shape, x.dtype.name) for x in leaves0)
        for d in datas[1:]:
            ls, td = jax.tree.flatten(d)
            if td != treedef or \
                    tuple((x.shape, x.dtype.name) for x in ls) != shapes:
                return None                 # ragged: not the hot path
        k = len(items)

        if self._mesh is not None:
            b = leaves0[0].shape[0]
            if (k * b) % self._n == 0 and \
                    all(x.shape[0] == b for x in leaves0):
                return self._stack_sharded(datas, leaves0, treedef, k)

        def alloc():
            return [np.empty((x.shape[0] * k,) + x.shape[1:], x.dtype)
                    for x in leaves0]

        if self._reuse:
            key = (k, treedef, shapes)
            slot = self._slots.get(key)
            if slot is None:
                # [two buffer sets, next index, last batch per set]
                slot = self._slots[key] = [(alloc(), alloc()), 0,
                                           [None, None]]
            idx = slot[1]
            bufs = slot[0][idx]
            slot[1] ^= 1
            if slot[2][idx] is not None:
                jax.block_until_ready(slot[2][idx])
        else:
            bufs = alloc()
        for i, d in enumerate(datas):
            for buf, leaf in zip(bufs, jax.tree.leaves(d)):
                b = leaf.shape[0]
                buf[i * b:(i + 1) * b] = leaf
        t0 = time.monotonic()
        tree = jax.tree.unflatten(treedef, bufs)
        if self._mesh is not None:
            # Rules divisibility fallback, staging edition: rows the
            # mesh can't split land replicated so the P(None) compiled
            # variant sees its expected sharding.
            from jax.sharding import NamedSharding, PartitionSpec
            out = jax.device_put(
                tree, NamedSharding(self._mesh, PartitionSpec()))
        else:
            out = jax.device_put(tree)
        self.last_device_put_s = time.monotonic() - t0
        if self._reuse:
            slot[2][idx] = out
        return out

    def _stack_sharded(self, datas, leaves0, treedef, k) -> PyTree:
        """SPMD staging: write each item's rows into the per-device
        shard buffer(s) they land on, ship one ``device_put`` per mesh
        device, and assemble global arrays with an explicit
        ``NamedSharding(mesh, P('data'))`` via
        ``make_array_from_single_device_arrays`` — the jitted shard_map
        step sees exactly the sharding it was compiled for.

        Buffers are freshly allocated per stack: sharded staging is
        only reachable in SPMD mode, which forces multi-device CPU (or
        real accelerators where per-shard transfers copy anyway), and
        the alias-vs-copy ping-pong discipline of the single-device
        path would need one event per shard for no measured win."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh, n = self._mesh, self._n
        b = leaves0[0].shape[0]
        rows = k * b
        r = rows // n
        shard_bufs = [[np.empty((r,) + x.shape[1:], x.dtype)
                       for x in leaves0] for _ in range(n)]
        for i, d in enumerate(datas):
            for j, leaf in enumerate(jax.tree.leaves(d)):
                lo = i * b                      # item rows [lo, lo+b)
                for s in range(lo // r, (lo + b - 1) // r + 1):
                    a = max(lo, s * r)          # overlap with shard s
                    z = min(lo + b, (s + 1) * r)
                    shard_bufs[s][j][a - s * r:z - s * r] = \
                        leaf[a - lo:z - lo]
        devices = mesh.devices.flatten()
        t0 = time.monotonic()
        per_dev = [jax.device_put(shard_bufs[s], devices[s])
                   for s in range(n)]
        global_leaves = []
        for j, x in enumerate(leaves0):
            sharding = NamedSharding(mesh, P("data"))
            global_leaves.append(jax.make_array_from_single_device_arrays(
                (rows,) + x.shape[1:], sharding,
                [per_dev[s][j] for s in range(n)]))
        self.last_device_put_s = time.monotonic() - t0
        return jax.tree.unflatten(treedef, global_leaves)

    def reshard(self, tree: PyTree) -> PyTree:
        """SPMD fallback for batches that bypassed sharded host staging
        (device-array leaves from inproc thread actors, ragged trees):
        one resharding ``device_put`` onto the mesh, sharded on the
        leading axis when the rows divide, replicated otherwise — a
        batch left committed to one device would collide with the
        mesh-wide params at dispatch."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        leaves = jax.tree.leaves(tree)
        rows = leaves[0].shape[0]
        if rows % self._n == 0 and \
                all(x.shape[0] == rows for x in leaves):
            sharding = NamedSharding(self._mesh, P("data"))
        else:
            sharding = NamedSharding(self._mesh, P())
        return jax.device_put(tree, sharding)


def _stack(items: List[TrajectoryItem],
           stager: Optional[_HostStager] = None) -> PyTree:
    import jax
    import jax.numpy as jnp

    if len(items) == 1 and (stager is None or stager._mesh is None):
        # SPMD staging must see even single items so the batch lands
        # pre-sharded (or explicitly replicated) on the mesh.
        return items[0].data

    if stager is not None:
        staged = stager.stack(items)
        if staged is not None:
            return staged

    def cat(*xs):
        # fallback: host concatenate for numpy leaves (one copy, feeding
        # the jit's host->device transfer), device concatenate otherwise
        if isinstance(xs[0], np.ndarray):
            return np.concatenate(xs, axis=0)
        return jnp.concatenate(xs, axis=0)

    out = jax.tree.map(cat, *[it.data for it in items])
    if stager is not None and stager._mesh is not None:
        out = stager.reshard(out)
    return out


class Learner:
    """One learner worker: drains a ``Transport`` with dynamic
    batching, trains, publishes versioned params, reports telemetry.

    Construction builds the params/optimizer/train-step state and the
    learner's own ``ParameterStore`` (available as ``self.store`` for
    wiring the actor pool / inference service); ``attach`` binds the
    pool (and optional service) once they exist; ``run`` executes the
    training loop end to end, owning the start/stop/join/close
    lifecycle exactly as ``run_async_training`` always did.

    ``exchange`` (a ``group.GradientExchange``) switches the update
    from the fused donated ``train_step`` to the data-parallel split:
    jitted backward pass -> host gradient leaves -> synchronous
    exchange (mean over the group, stale contributions dropped by the
    hub's rule) -> donated ``apply_step`` of the *mean* -> publish at
    the exchange-delegated version. Every learner applies the same
    broadcast mean with the same optimizer state, so the replicas stay
    bit-identical without ever shipping parameters between learners.

    An *in-XLA* exchange (``group.CollectiveExchange``) selects SPMD
    mode instead: one process, one donated ``shard_map`` train step
    over a ``('data',)`` device mesh. The batch is staged pre-sharded
    on the leading trajectory axis, params/opt state stay replicated,
    and the gradient mean is a fused ``lax.pmean`` — the same
    mathematical update as an N-learner group at equal global batch,
    with zero host round-trips (and zero TCP frames) in the gradient
    path. The exchange object only delegates version numbers and
    records per-round latency; stale-drop never fires because nothing
    can be stale.
    """

    def __init__(self, *, arch, icfg, num_actions: int, num_envs: int,
                 num_actors: int, transport, seed: int = 0,
                 learner_id: int = 0, num_learners: int = 1,
                 slot_base: int = 0, actor_mode: str = "unroll",
                 max_batch_trajs: int = 4, batch_linger_s: float = 0.0,
                 donate: bool = True, start_step: int = 0,
                 initial_params: Optional[PyTree] = None,
                 initial_opt_state: Optional[PyTree] = None,
                 exchange=None, registry: Optional[Registry] = None,
                 wire_codec: str = "none", vtrace_impl: str = "auto",
                 trace=None, phase_timing: bool = False, profile=None):
        import jax
        import jax.numpy as jnp

        from repro.core import learner as learner_lib
        from repro.models import backbone as bb
        from repro.models import common as pcommon

        if max_batch_trajs < 1:
            raise ValueError(f"max_batch_trajs must be >= 1, got "
                             f"{max_batch_trajs}")
        self.arch = arch
        self.icfg = icfg
        self.learner_id = learner_id
        self.num_learners = num_learners
        self.slot_base = slot_base
        self.actor_mode = actor_mode
        self.donate = donate
        self.batch_linger_s = batch_linger_s
        self.queue = transport
        self._exchange = exchange
        self.wire_codec = wire_codec
        self.vtrace_impl = vtrace_impl
        # learner-local randomness (NOT param init): fold the learner id
        # into the run seed so two learners of one group never share a
        # stream. Today this feeds the grouped inference service's
        # action-sampling key (see runtime._setup); any future
        # learner-local stochastic op must draw from it too.
        self.key = jax.random.fold_in(jax.random.key(seed), learner_id)

        specs = bb.backbone_specs(arch, num_actions)
        if initial_params is not None:
            params = initial_params
        else:
            # param init stays at the RAW seed on every learner:
            # data-parallel replicas must start identical, and
            # --learners 1 must bit-match the single-learner run
            params = pcommon.init_params(specs, jax.random.key(seed))
        replay_on = icfg.replay_fraction > 0.0
        spmd_on = exchange is not None and getattr(exchange, "in_xla",
                                                   False)
        self._spmd_mesh = None
        self._train_step_repl = None
        if spmd_on:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.launch.mesh import make_data_mesh
            from repro.sharding.rules import Rules

            mesh = make_data_mesh(exchange.num_devices)
            self._spmd_mesh = mesh
            self._spmd_rules = Rules(mesh)
            # the published snapshot is re-homed on one device so the
            # inference service's forward doesn't run replicated over
            # the whole mesh
            self._spmd_publish_dev = jax.devices()[0]
            # params (and below, opt state) live replicated over the
            # mesh from the start: a donated shard_map step whose
            # arguments already carry the compiled sharding never
            # reshards on entry
            params = jax.device_put(params, NamedSharding(mesh, P()))
            if replay_on:
                sharded, opt = learner_lib.build_spmd_replay_train_step(
                    arch, icfg, num_actions, mesh,
                    vtrace_impl=vtrace_impl)
                repl, _ = learner_lib.build_spmd_replay_train_step(
                    arch, icfg, num_actions, mesh, optimizer=opt,
                    vtrace_impl=vtrace_impl, batch_replicated=True)
                don = (0, 2)
            else:
                sharded, opt = learner_lib.build_spmd_train_step(
                    arch, icfg, num_actions, mesh,
                    vtrace_impl=vtrace_impl)
                repl, _ = learner_lib.build_spmd_train_step(
                    arch, icfg, num_actions, mesh, optimizer=opt,
                    vtrace_impl=vtrace_impl, batch_replicated=True)
                don = (0, 1)
            if donate:
                self._train_step = jax.jit(sharded, donate_argnums=don)
                self._train_step_repl = jax.jit(repl, donate_argnums=don)
            else:
                self._train_step = jax.jit(sharded)
                self._train_step_repl = jax.jit(repl)
            self._grad_step = None
            self._apply_step = None
        elif exchange is None:
            if replay_on:
                # replay path: train_step(params, target_params,
                # opt_state, step, batch) — the target (argnum 1) is a
                # long-lived read-only snapshot, so only params and
                # opt_state are donated
                train_step, opt = learner_lib.build_replay_train_step(
                    arch, icfg, num_actions, vtrace_impl=vtrace_impl)
                if donate:
                    train_step = jax.jit(train_step, donate_argnums=(0, 2))
                else:
                    train_step = jax.jit(train_step)
            else:
                train_step, opt = learner_lib.build_train_step(
                    arch, icfg, num_actions, vtrace_impl=vtrace_impl)
                if donate:
                    train_step = jax.jit(train_step, donate_argnums=(0, 1))
                else:
                    train_step = jax.jit(train_step)
            self._train_step = train_step
            self._grad_step = None
            self._apply_step = None
        else:
            if replay_on:
                grad_step, apply_step, opt = \
                    learner_lib.build_replay_grad_apply_steps(
                        arch, icfg, num_actions, vtrace_impl=vtrace_impl)
            else:
                grad_step, apply_step, opt = \
                    learner_lib.build_grad_apply_steps(
                        arch, icfg, num_actions, vtrace_impl=vtrace_impl)
            self._train_step = None
            self._grad_step = jax.jit(grad_step)
            if donate:
                self._apply_step = jax.jit(apply_step,
                                           donate_argnums=(0, 1))
            else:
                self._apply_step = jax.jit(apply_step)
        # one jitted whole-tree device copy: the decoupling between the
        # learner's donated working tree and every reference that
        # escapes (store, service, on_update). XLA never aliases
        # non-donated outputs to inputs, so the copy's buffers are
        # independent by construction.
        self._snapshot = jax.jit(lambda tree: jax.tree.map(jnp.copy, tree))
        self._params = params
        if initial_opt_state is not None:
            # checkpoint resume: restore the optimizer moments instead
            # of re-initializing — device_put so donation never aliases
            # the caller's (possibly mmapped) host buffers
            self._opt_state = jax.device_put(initial_opt_state)
        else:
            self._opt_state = opt.init(params)
        if spmd_on:
            from jax.sharding import NamedSharding, PartitionSpec as P
            self._opt_state = jax.device_put(
                self._opt_state, NamedSharding(self._spmd_mesh, P()))
        self.store = ParameterStore(
            self._spmd_publish(params) if spmd_on
            else (self._snapshot(params) if donate else params),
            version=start_step, wire_codec=wire_codec)
        self.start_step = start_step
        self.tracker = MultiTracker(num_actors, num_envs,
                                    slot_base=slot_base)
        self._buckets = _buckets(max_batch_trajs)
        self._stager = _HostStager(mesh=self._spmd_mesh)
        self._frames_per_traj = num_envs * icfg.unroll_length
        self._num_envs = num_envs
        if replay_on:
            # replay RNG identity is (seed, learner_id) — the
            # fold_replay_seed discipline keeps group replicas on
            # deterministic per-replica streams (and since every replica
            # trains the same exchanged mean, giving them the SAME
            # stream isn't needed for digest-identity; what matters is
            # that each is deterministic across a restart)
            self._replay = replay_lib.ReplayBuffer(
                icfg.replay_capacity, seed=seed, learner_id=learner_id,
                reuse_limit=icfg.replay_reuse,
                priority=icfg.replay_priority)
            self._fresh_max = max(1, int(round(
                self._buckets[0] * (1.0 - icfg.replay_fraction))))
            # IMPACT target: a periodic copy of the learner params
            # supplies the V-trace baseline for replayed rows; synced
            # every icfg.replay_target_period updates (a pure function
            # of the update count, so group replicas sync in lockstep)
            self._target_params = self._snapshot(params)
        else:
            self._replay = None
            self._fresh_max = None
            self._target_params = None
        self._target_syncs = 0
        self.frames_trained = 0
        self.pool = None
        self.service = None

        # telemetry state (same pinned snapshot keys the runtime always
        # reported, but the storage now lives in a metrics registry: the
        # lag/batch histograms ARE registry instruments — the hot-path
        # `hist[k] += 1` writes the registry — and everything else is a
        # pull-time producer, so the live /metrics endpoint and the
        # end-of-run snapshot can never disagree.
        self.obs_registry = registry if registry is not None else Registry()
        self.lag_hist = self.obs_registry.int_histogram(
            "learner.lag_hist").counts
        self.batch_hist = self.obs_registry.int_histogram(
            "learner.batch_hist").counts
        self.updates = start_step
        self.frames_consumed = 0
        self._steady_t0: Optional[float] = None
        self._steady_updates0 = 0
        self._steady_frames0 = 0
        self._steady_trained0 = 0
        self._first_t0: Optional[float] = None
        self._first_updates0 = 0
        self._first_frames0 = 0
        self._first_trained0 = 0
        self.metrics: Dict = {}
        # flight recorder hooks (all optional, see repro.obs)
        self.trace = trace                  # TraceRecorder or None
        self._phase_timing = bool(phase_timing)
        self._profile = profile             # ProfileHook or None
        self._phase_acc = {"collect": 0.0, "host_stage": 0.0,
                           "device_put": 0.0, "step": 0.0, "publish": 0.0}
        self._phase_n = 0
        reg = self.obs_registry
        reg.register_producer("learner", self._core_telemetry)
        reg.register_producer(
            "queue", lambda: (self.queue.snapshot()
                              if self.queue is not None else None))
        reg.register_producer(
            "actors", lambda: (self.pool.stats()
                               if self.pool is not None else {}))
        reg.register_producer(
            "inference", lambda: (self.service.snapshot()
                                  if self.service is not None else None))
        reg.register_producer(
            "exchange", lambda: (self._exchange.snapshot()
                                 if self._exchange is not None else None))
        reg.register_producer("replay", self._replay_telemetry)

    # ------------------------------------------------------------------

    def attach(self, pool, service=None) -> None:
        """Bind the actor pool (and optional inference service) this
        learner drives; both were built against ``self.store`` and
        ``self.queue``."""
        self.pool = pool
        self.service = service

    # ------------------------------------------------------------------

    def _core_telemetry(self) -> Dict:
        """The ``learner`` registry producer: counts, rates, version."""
        now = time.monotonic()
        if self._steady_t0 is not None:
            dt, u0, f0 = (now - self._steady_t0, self._steady_updates0,
                          self._steady_frames0)
        elif self._first_t0 is not None:
            dt, u0, f0 = (now - self._first_t0, self._first_updates0,
                          self._first_frames0)
        else:
            dt, u0, f0 = 0.0, 0, 0
        return {
            "updates": self.updates,
            "frames_consumed": self.frames_consumed,
            "updates_per_sec": ((self.updates - u0) / dt
                                if dt > 0 else 0.0),
            "frames_per_sec": ((self.frames_consumed - f0) / dt
                               if dt > 0 else 0.0),
            "param_version": self.store.version,
            "wire_codec": self.wire_codec,
            "param_wire_bytes": self.store.serialized_wire_bytes,
            "param_raw_bytes": self.store.serialized_raw_bytes,
        }

    def _replay_telemetry(self) -> Optional[Dict]:
        """The ``replay`` registry producer — None (and therefore
        omitted from /metrics and the snapshot) when replay is off, so
        the pinned single-learner key set is untouched."""
        if self._replay is None:
            return None
        now = time.monotonic()
        if self._steady_t0 is not None:
            dt, t0 = now - self._steady_t0, self._steady_trained0
        elif self._first_t0 is not None:
            dt, t0 = now - self._first_t0, self._first_trained0
        else:
            dt, t0 = 0.0, 0
        snap = self._replay.snapshot()
        snap["fraction"] = self.icfg.replay_fraction
        snap["fresh_max"] = self._fresh_max
        snap["frames_trained"] = self.frames_trained
        # reuse ratio: frames the optimizer saw per env frame consumed
        # (1.0 = one-pass IMPALA; ~1/(1-fraction) in steady state)
        snap["reuse_ratio"] = (self.frames_trained / self.frames_consumed
                               if self.frames_consumed else 0.0)
        snap["trained_frames_per_sec"] = ((self.frames_trained - t0) / dt
                                          if dt > 0 else 0.0)
        snap["target_syncs"] = self._target_syncs
        snap["target_period"] = self.icfg.replay_target_period
        return snap

    def telemetry_snapshot(self) -> Dict:
        """The pinned snapshot key set, assembled from one registry
        pull — the same storage the live /metrics endpoint reads."""
        col = self.obs_registry.collect()
        core = col.get("learner", {})
        lag_hist = col.get("learner.lag_hist", {})
        n_lags = sum(lag_hist.values())
        snap = {
            "learner_updates": core.get("updates", self.updates),
            "frames_consumed": core.get("frames_consumed",
                                        self.frames_consumed),
            "updates_per_sec": core.get("updates_per_sec", 0.0),
            "frames_per_sec": core.get("frames_per_sec", 0.0),
            "batch_size_hist": dict(col.get("learner.batch_hist", {})),
            "lag": {
                "hist": dict(sorted(lag_hist.items())),
                "mean": (sum(k * v for k, v in lag_hist.items())
                         / n_lags if n_lags else 0.0),
                "max": max(lag_hist) if lag_hist else 0,
                "measured": n_lags,
            },
            "queue": col.get("queue", {}),
            "actors": col.get("actors", {}),
            "param_version": core.get("param_version",
                                      self.store.version),
            "actor_mode": self.actor_mode,
            "donate": self.donate,
        }
        if "inference" in col:
            snap["inference"] = col["inference"]
        if "replay" in col:
            # replay runs only: reuse ratio, priority/staleness hists,
            # occupancy — the producer returns None (omitted) otherwise
            snap["replay"] = col["replay"]
        if self._exchange is not None:
            # grouped only: the single-learner snapshot keys must stay
            # exactly what run_async_training always reported
            snap["learner_id"] = self.learner_id
            snap["slot_base"] = self.slot_base
            snap["exchange"] = col.get("exchange",
                                       self._exchange.snapshot())
            if self._spmd_mesh is not None:
                # SPMD runs surface the same ``group`` section the
                # multi-process topologies emit, so dashboards key on
                # one shape; backend label tells them apart
                ex = snap["exchange"] or {}
                snap["group"] = {
                    "num_learners": 1,
                    "publisher": self.learner_id,
                    "exchange_backend": ex.get("exchange_backend",
                                               "collective"),
                    "spmd_devices": ex.get(
                        "devices", int(self._spmd_mesh.devices.size)),
                    "rounds": ex.get("rounds", 0),
                }
        if "supervisor" in col:
            # supervised only: restart/failover/lease-reap counts ride
            # the snapshot so a final telemetry dump (and the group
            # parent's merge) shows exactly what the run survived;
            # unsupervised runs keep the pinned key set untouched
            snap["supervisor"] = col["supervisor"]
        if self._phase_timing:
            # gated on the flight recorder being enabled: the pinned
            # key-set equivalence (group-of-one vs single run) holds for
            # runs without obs, which never see this key
            n = self._phase_n
            snap["phases"] = {
                "updates_timed": n,
                "total_s": dict(self._phase_acc),
                "mean_ms": {k: (1e3 * v / n if n else 0.0)
                            for k, v in self._phase_acc.items()},
            }
        return snap

    # ------------------------------------------------------------------

    def _raise_worker_errors(self) -> None:
        self.pool.raise_errors()
        if self.service is not None:
            self.service.raise_errors()

    # ------------------------------------------------------------------
    # SPMD mode helpers

    def _spmd_publish(self, params):
        """Snapshot + re-home on one device: the store (and through it
        the inference service's jit and every actor pull) sees a plain
        single-device tree, not an array replicated over the mesh —
        a replicated forward would run on every mesh device."""
        import jax
        return jax.device_put(self._snapshot(params),
                              self._spmd_publish_dev)

    def _spmd_step_for(self, batch):
        """Pick the compiled variant for this batch's leading row count
        via the sharding rules: rows the ``('data',)`` mesh divides run
        the batch-sharded step; anything else (the Rules divisibility
        fallback, ``P(None)``) runs the batch-replicated variant —
        every device computes the full-batch gradient and the pmean is
        an identity, so semantics match the fused single step exactly."""
        import jax

        leaves = jax.tree.leaves(batch)
        rows = leaves[0].shape[0]
        if all(x.shape[0] == rows for x in leaves) and \
                self._spmd_rules.spec(("batch",), (rows,))[0] is not None:
            return self._train_step
        return self._train_step_repl

    def _warm(self, params, opt_state) -> None:
        """Pre-compile the train step for every batch bucket on
        throwaway copies (donation would otherwise consume the real
        trees), so benchmarks measure steady state, not XLA."""
        import jax
        import jax.numpy as jnp

        first = None
        while first is None:
            self._raise_worker_errors()
            first = self.queue.get(timeout=0.5)
        for b in self._buckets:
            if self._spmd_mesh is not None:
                # stage through the sharded stager so each bucket's
                # compile sees the exact input sharding of steady state
                warm = _stack([first] * b, self._stager)
            else:
                warm = _stack([first] * b) if b > 1 else first.data
            if self._replay is not None:
                # the replay mask is batch DATA (not a static shape), so
                # an all-zero warm mask compiles the one program each
                # bucket ever needs
                warm = dict(warm)
                warm["replay_mask"] = np.zeros(b * self._num_envs,
                                               np.float32)
            if self._spmd_mesh is not None:
                step_fn = self._spmd_step_for(warm)
                if self._replay is not None:
                    out = step_fn(self._snapshot(params),
                                  self._target_params,
                                  self._snapshot(opt_state),
                                  jnp.int32(0), warm)
                else:
                    out = step_fn(self._snapshot(params),
                                  self._snapshot(opt_state),
                                  jnp.int32(0), warm)
                jax.block_until_ready(out[0])
            elif self._exchange is None:
                if self._replay is not None:
                    out = self._train_step(self._snapshot(params),
                                           self._target_params,
                                           self._snapshot(opt_state),
                                           jnp.int32(0), warm)
                else:
                    out = self._train_step(self._snapshot(params),
                                           self._snapshot(opt_state),
                                           jnp.int32(0), warm)
                jax.block_until_ready(out[0])   # compile only; discard
            else:
                if self._replay is not None:
                    grads, _ = self._grad_step(params, self._target_params,
                                               warm)
                else:
                    grads, _ = self._grad_step(params, warm)
                out = self._apply_step(self._snapshot(params),
                                       self._snapshot(opt_state),
                                       jnp.int32(0), grads)
                jax.block_until_ready(out[0])
        self.queue.requeue_front(first)

    def _update_once(self, batch, jnp, jax, timings=None):
        """One training update on ``batch``: fused when alone, split
        backward/exchange/apply when grouped. Returns (published
        params, metrics) or None when the exchange shut down.

        ``timings`` (a dict, flight-recorder runs only) receives
        step0/step1/published stamps. On the fused path these bracket
        the async *dispatch* — blocking for the device would tax the
        pipeline the recorder exists to observe; the split path's
        ``np.asarray`` already forces the backward pass, so its stamps
        are real."""
        if self._spmd_mesh is not None:
            # SPMD: the whole group update is ONE donated shard_map
            # dispatch — backward, in-XLA pmean, optimizer. Nothing
            # crosses the host, so the exchange only delegates the
            # version number and books the round.
            if timings is not None:
                timings["step0"] = time.monotonic()
            t0 = time.monotonic()
            step_fn = self._spmd_step_for(batch)
            if self._replay is not None:
                self._params, self._opt_state, metrics = step_fn(
                    self._params, self._target_params, self._opt_state,
                    jnp.int32(self.updates), batch)
            else:
                self._params, self._opt_state, metrics = step_fn(
                    self._params, self._opt_state,
                    jnp.int32(self.updates), batch)
            reduced = self._exchange.allreduce((),
                                               round_idx=self.updates)
            if reduced is None:
                return None                 # exchange shutting down
            _, version = reduced
            published = (self._spmd_publish(self._params) if self.donate
                         else jax.device_put(self._params,
                                             self._spmd_publish_dev))
            # grad_norm is computed from the pmean'd mean: waiting on it
            # waits on the collective completing on every shard, so the
            # observed round latency is the real all-reduce+apply time
            jax.block_until_ready(metrics["opt/grad_norm"])
            self._exchange.observe_round_s(time.monotonic() - t0,
                                           round_idx=self.updates)
            if timings is not None:
                timings["step1"] = time.monotonic()
            self.store.publish_at(published, version)
            if timings is not None:
                timings["published"] = time.monotonic()
            return published, metrics
        if self._exchange is None:
            if timings is not None:
                timings["step0"] = time.monotonic()
            if self._replay is not None:
                self._params, self._opt_state, metrics = self._train_step(
                    self._params, self._target_params, self._opt_state,
                    jnp.int32(self.updates), batch)
            else:
                self._params, self._opt_state, metrics = self._train_step(
                    self._params, self._opt_state, jnp.int32(self.updates),
                    batch)
            published = (self._snapshot(self._params) if self.donate
                         else self._params)
            if timings is not None:
                timings["step1"] = time.monotonic()
            self.store.publish(published)
            if timings is not None:
                timings["published"] = time.monotonic()
            return published, metrics
        if timings is not None:
            timings["step0"] = time.monotonic()
        if self._replay is not None:
            grads, metrics = self._grad_step(self._params,
                                             self._target_params, batch)
        else:
            grads, metrics = self._grad_step(self._params, batch)
        leaves, treedef = jax.tree.flatten(grads)
        # np.asarray forces the backward pass and lands the gradient
        # leaves host-side (views on the CPU backend, copies elsewhere)
        flat = [np.asarray(x) for x in leaves]
        reduced = self._exchange.allreduce(flat, round_idx=self.updates)
        if reduced is None:
            return None                     # group shutting down
        mean_leaves, version = reduced
        mean = jax.tree.unflatten(treedef, list(mean_leaves))
        self._params, self._opt_state, ametrics = self._apply_step(
            self._params, self._opt_state, jnp.int32(self.updates), mean)
        metrics = dict(metrics)
        metrics.update(ametrics)
        published = (self._snapshot(self._params) if self.donate
                     else self._params)
        if timings is not None:
            timings["step1"] = time.monotonic()
        # versioned publish delegation: the exchange's designated
        # publisher numbers the rounds; every learner's store publishes
        # at exactly that version, so the group's actors observe one
        # monotonic version stream no matter which learner they pull
        # from
        self.store.publish_at(published, version)
        if timings is not None:
            timings["published"] = time.monotonic()
        return published, metrics

    def _sample_replay(self, num_fresh: int, version_now: int):
        """Plan and draw the replayed top-up for a batch of
        ``num_fresh`` online trajectories; None = train pure online
        this round (fraction 0, buffer still filling, or starved)."""
        if self._replay is None:
            return None
        n_rep = replay_lib.plan_mix(
            num_fresh, self._buckets[0], self.icfg.replay_fraction,
            self._replay.num_sampleable())
        if n_rep < 1:
            return None
        return self._replay.sample_items(n_rep, version_now=version_now)

    def _replay_bookkeeping(self, metrics, samples, fresh_items):
        """Post-step replay accounting: pop the per-trajectory
        advantage-magnitude metric (it is (B,)-shaped and must not
        reach scalar metric consumers), re-score the replayed slots
        with it, and insert the freshly trained trajectories with their
        measured priority and their online pass pre-counted
        (``uses=1``), so ``--replay-reuse K`` caps *total*
        consumptions."""
        metrics = dict(metrics)
        mags = metrics.pop("vtrace/traj_adv_mag", None)
        n_rep = len(samples) if samples else 0
        per = None
        if mags is not None:
            # row r of the stacked batch belongs to trajectory r //
            # num_envs (the stager lays item i at rows [i*b, (i+1)*b))
            per = np.asarray(mags, np.float64).reshape(
                n_rep + len(fresh_items), self._num_envs).mean(axis=1)
        if n_rep and per is not None:
            self._replay.update_priorities(
                [s.uid for s in samples], per[:n_rep])
        for j, it in enumerate(fresh_items):
            self._replay.add_item(
                it,
                priority=(float(per[n_rep + j]) if per is not None
                          else None),
                uses=1)
        return metrics

    def _record_obs(self, items, version_now: int, t_deq: float,
                    t_col: float, t_stk: float,
                    timings: Dict[str, float]) -> None:
        """Fold one update's stamps into the phase accumulators and the
        trace recorder (sampled items only)."""
        step0 = timings.get("step0", t_stk)
        step1 = timings.get("step1", step0)
        pub = timings.get("published", step1)
        if self._phase_timing:
            acc = self._phase_acc
            acc["collect"] += t_col - t_deq
            acc["host_stage"] += t_stk - t_col
            acc["device_put"] += self._stager.last_device_put_s
            acc["step"] += step1 - step0
            acc["publish"] += pub - step1
            self._phase_n += 1
        if self.trace is not None:
            for it in items:
                if getattr(it, "trace", None) is not None:
                    self.trace.record_item(
                        it, dequeued=t_deq, collected=t_col,
                        step0=step0, step1=step1, published=pub,
                        lag=version_now - it.param_version)

    def run(self, steps: int, *, warm_buckets: bool = False,
            on_update: Optional[Callable] = None,
            should_stop: Optional[Callable[[], bool]] = None,
            on_checkpoint: Optional[Callable] = None,
            ckpt_every: int = 0) -> Tuple[Dict, Dict]:
        """Train until ``steps`` total updates (or ``should_stop``).
        Owns the full worker lifecycle: starts the service/pool, runs
        the loop, then stops/joins/closes in the only order that never
        tears a frame. Returns (last metrics, final telemetry).

        ``on_checkpoint(step, params, opt_state, version)`` fires every
        ``ckpt_every`` updates (host numpy trees, decoupled from the
        donated working state) — the periodic-checkpoint hook; the
        4-arg ``on_update`` signature stays exactly as it always was."""
        import jax
        import jax.numpy as jnp

        if self.pool is None:
            raise RuntimeError("attach(pool) before run()")
        if self.service is not None:
            self.service.start()
        self.pool.start()
        try:
            if warm_buckets:
                self._warm(self._params, self._opt_state)

            # flight-recorder stamps only when something consumes them:
            # the plain hot path stays free of per-update clock reads
            want_t = self._phase_timing or self.trace is not None
            while self.updates < steps:
                if should_stop is not None and should_stop():
                    break
                self._raise_worker_errors()
                item = self.queue.get(timeout=0.5)
                if item is None:
                    continue
                t_deq = time.monotonic() if want_t else 0.0
                # replay caps fresh collection below the top bucket —
                # the batch is topped back up with replayed rows, which
                # is exactly where the env-frame saving comes from
                items = _collect_batch(self.queue, self._buckets, item,
                                       self.batch_linger_s,
                                       max_items=self._fresh_max)
                k = len(items)
                t_col = time.monotonic() if want_t else 0.0

                version_now = self.store.version
                for it in items:
                    self.lag_hist[version_now - it.param_version] += 1
                    self.tracker.update(it.actor_id, it.data["rewards"],
                                        it.data["done"])
                samples = self._sample_replay(k, version_now)
                train_items = ([s.item for s in samples] + items
                               if samples else items)
                if want_t:
                    self._stager.last_device_put_s = 0.0
                batch = _stack(train_items, self._stager)
                if self._replay is not None:
                    # replayed rows sit FIRST in the stacked batch; the
                    # mask rides as data so every bucket keeps a single
                    # compiled program
                    n_rep = len(samples) if samples else 0
                    mask = np.zeros(len(train_items) * self._num_envs,
                                    np.float32)
                    mask[:n_rep * self._num_envs] = 1.0
                    batch = dict(batch)
                    batch["replay_mask"] = mask
                t_stk = time.monotonic() if want_t else 0.0
                if self._profile is not None:
                    self._profile.on_step(self.updates)
                timings = {} if want_t else None
                stepped = self._update_once(batch, jnp, jax,
                                            timings=timings)
                if stepped is None:
                    break                   # exchange shut down under us
                published, metrics = stepped
                if self._replay is not None:
                    metrics = self._replay_bookkeeping(metrics, samples,
                                                       items)
                self.metrics = metrics
                self.updates += 1
                if self._replay is not None and \
                        self.updates % self.icfg.replay_target_period == 0:
                    # IMPACT target sync: a pure function of the update
                    # count, so group replicas flip targets in lockstep.
                    # `published` is already a decoupled snapshot (or
                    # the functionally-replaced live tree), never a
                    # donated buffer. SPMD re-replicates it over the
                    # mesh: the shard_map step was compiled for a
                    # P()-sharded target, and feeding it the
                    # single-device publish copy would recompile.
                    if self._spmd_mesh is not None:
                        from jax.sharding import (NamedSharding,
                                                  PartitionSpec)
                        self._target_params = jax.device_put(
                            published, NamedSharding(self._spmd_mesh,
                                                     PartitionSpec()))
                    else:
                        self._target_params = published
                    self._target_syncs += 1
                self.frames_consumed += k * self._frames_per_traj
                self.frames_trained += (len(train_items) *
                                        self._frames_per_traj)
                self.batch_hist[len(train_items)] += 1
                if want_t:
                    self._record_obs(items, version_now, t_deq, t_col,
                                     t_stk, timings)
                if self._steady_t0 is None:
                    jax.block_until_ready(self._params)
                    if self._first_t0 is None:
                        # first update includes the learner's jit compile
                        self._first_t0 = time.monotonic()
                        self._first_updates0 = self.updates
                        self._first_frames0 = self.frames_consumed
                        self._first_trained0 = self.frames_trained
                    if all(f > 0 for f in self.pool.frames):
                        # every worker is past import/compile and
                        # producing
                        self._steady_t0 = time.monotonic()
                        self._steady_updates0 = self.updates
                        self._steady_frames0 = self.frames_consumed
                        self._steady_trained0 = self.frames_trained
                if on_update is not None:
                    on_update(self.updates, published, self.metrics,
                              self.telemetry_snapshot)
                if on_checkpoint is not None and ckpt_every > 0 and \
                        self.updates % ckpt_every == 0:
                    on_checkpoint(self.updates,
                                  jax.tree.map(np.asarray, published),
                                  self.opt_state_host(),
                                  self.store.version)
            # snapshot before teardown: pool.join waits out in-flight
            # unrolls and put timeouts, which would silently pad the
            # steady-state dt
            jax.block_until_ready(self._params)
            final_telemetry = self.telemetry_snapshot()
        finally:
            # order matters: signal stop (a serializing transport flips
            # to discard mode so producer processes can always flush and
            # exit; the inference service wakes every blocked client
            # with a None reply), join the workers, and only then tear
            # the transport down — a wire closed under a live producer
            # can tear frames
            if self._profile is not None:
                self._profile.stop()
            self.pool.stop()
            if self.service is not None:
                self.service.stop()
            if self._exchange is not None:
                self._exchange.close()
            self.pool.join()
            self.queue.close()
        self._raise_worker_errors()
        return self.metrics, final_telemetry

    # ------------------------------------------------------------------

    def published_host(self) -> PyTree:
        """The latest published params as host numpy leaves — what a
        group worker ships to the parent for checkpointing."""
        params, _version = self.store.pull()
        import jax

        return jax.tree.map(np.asarray, params)

    def opt_state_host(self) -> PyTree:
        """The live optimizer state as host numpy leaves (copies, so a
        checkpoint writer never races the donated working tree)."""
        import jax

        return jax.tree.map(lambda x: np.array(x), self._opt_state)
