"""repro.distributed — the real asynchronous actor-learner runtime.

Decoupled acting and learning in one process (paper §3): an actor thread
pool feeds a bounded backpressured trajectory queue; a dynamic-batching
learner drains it; parameters flow back through a versioned store so
policy lag is measured per trajectory rather than simulated.
"""
from repro.distributed.actor_pool import ActorPool, TrajectoryItem
from repro.distributed.paramstore import ParameterStore
from repro.distributed.runtime import MultiTracker, run_async_training
from repro.distributed.tqueue import POLICIES, TrajectoryQueue

__all__ = [
    "ActorPool",
    "TrajectoryItem",
    "ParameterStore",
    "MultiTracker",
    "run_async_training",
    "POLICIES",
    "TrajectoryQueue",
]
