"""repro.distributed — the real asynchronous actor-learner runtime.

Decoupled acting and learning (paper §3) as a layered pipeline:

  serde       TrajectoryItem <-> spec-described contiguous byte buffer,
              plus the CRC-checked wire frame header TCP messages use
  transport   put/get/backpressure/counters behind one interface —
              in-process deque (zero-copy), cross-process wire
              (serialized buffers, parent-side policy), or TCP socket
              (socket_transport: remote machines, reconnect, torn-frame
              detection)
  netserve    what a remote machine needs beyond the pipe: the CONFIG
              handshake that ships the whole run config, the inference
              service over sockets, and the remote actor entry point
  runner      the actor loop bodies (per-actor unroll, and the
              inference-mode host env stepper), shared by thread and
              process workers
  inference   the dynamic-batching InferenceService: one jitted batched
              per-step policy forward on the learner's device, fed by
              thread clients or serde frames from actor processes
  pools       ActorPool (threads) / ProcessActorPool (spawned workers)
  paramstore  versioned publish/pull (plus delegated ``publish_at`` for
              learner groups), and a serialized subscribe path
              (encoded once per version) for process actors
  learner     the Learner worker object: dynamic batch collection,
              donated (or split grad/apply) train step, versioned
              publish, telemetry — shared by the single-learner
              runtime and the multi-learner group
  group       LearnerGroup: N learner worker processes over disjoint
              actor-slot shards, gradients mean-reduced over the
              framed channel (GradientExchange: hub + spokes,
              stale-grad drop rule), one designated publisher
              numbering the version stream
  runtime     composition root: build env/store/service/transport/pool
              and run one Learner over them

Exports resolve lazily (PEP 562): importing ``repro.distributed.serde``
or ``.transport`` from an actor child process must not drag jax in.
"""
from typing import TYPE_CHECKING

_EXPORTS = {
    "ActorPool": "repro.distributed.actor_pool",
    "ProcessActorPool": "repro.distributed.procpool",
    "TrajectoryItem": "repro.distributed.serde",
    "encode_item": "repro.distributed.serde",
    "decode_item": "repro.distributed.serde",
    "encode_tree": "repro.distributed.serde",
    "decode_tree": "repro.distributed.serde",
    "decode_tree_into": "repro.distributed.serde",
    "tree_spec": "repro.distributed.serde",
    "ParameterStore": "repro.distributed.paramstore",
    "ACTOR_MODES": "repro.distributed.runtime",
    "Learner": "repro.distributed.learner",
    "MultiTracker": "repro.distributed.learner",
    "run_async_training": "repro.distributed.runtime",
    "GradientExchange": "repro.distributed.group",
    "NullExchange": "repro.distributed.group",
    "CollectiveExchange": "repro.distributed.group",
    "GradHub": "repro.distributed.group",
    "SpokeExchange": "repro.distributed.group",
    "ResilientExchange": "repro.distributed.group",
    "KillSafeEvent": "repro.distributed.supervise",
    "RestartPolicy": "repro.distributed.supervise",
    "Supervisor": "repro.distributed.supervise",
    "fold_restart_seed": "repro.distributed.supervise",
    "GroupTracker": "repro.distributed.group",
    "merge_telemetry": "repro.distributed.group",
    "shard_slots": "repro.distributed.group",
    "run_group_training": "repro.distributed.group",
    "run_actor_loop": "repro.distributed.runner",
    "run_inference_actor_loop": "repro.distributed.runner",
    "InferenceService": "repro.distributed.inference",
    "InferenceClient": "repro.distributed.inference",
    "InferenceReply": "repro.distributed.inference",
    "POLICIES": "repro.distributed.tqueue",
    "TrajectoryQueue": "repro.distributed.tqueue",
    "TRANSPORTS": "repro.distributed.transport",
    "Transport": "repro.distributed.transport",
    "InprocTransport": "repro.distributed.transport",
    "ShmTransport": "repro.distributed.transport",
    "make_transport": "repro.distributed.transport",
    "SocketTransport": "repro.distributed.socket_transport",
    "SocketActorClient": "repro.distributed.socket_transport",
    "SocketActorPool": "repro.distributed.procpool",
    "remote_actor_main": "repro.distributed.netserve",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    target = _EXPORTS.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(target), name)


def __dir__():
    return __all__


if TYPE_CHECKING:  # pragma: no cover — static imports for type checkers
    from repro.distributed.actor_pool import ActorPool
    from repro.distributed.group import (CollectiveExchange, GradHub,
                                         GradientExchange,
                                         GroupTracker, NullExchange,
                                         ResilientExchange, SpokeExchange,
                                         merge_telemetry,
                                         run_group_training, shard_slots)
    from repro.distributed.learner import Learner, MultiTracker
    from repro.distributed.netserve import remote_actor_main
    from repro.distributed.procpool import SocketActorPool
    from repro.distributed.socket_transport import (SocketActorClient,
                                                    SocketTransport)
    from repro.distributed.inference import (InferenceClient,
                                             InferenceReply,
                                             InferenceService)
    from repro.distributed.paramstore import ParameterStore
    from repro.distributed.procpool import ProcessActorPool
    from repro.distributed.runner import (run_actor_loop,
                                          run_inference_actor_loop)
    from repro.distributed.runtime import ACTOR_MODES, run_async_training
    from repro.distributed.supervise import (KillSafeEvent, RestartPolicy,
                                             Supervisor, fold_restart_seed)
    from repro.distributed.serde import (TrajectoryItem, decode_item,
                                         decode_tree, decode_tree_into,
                                         encode_item, encode_tree,
                                         tree_spec)
    from repro.distributed.tqueue import POLICIES, TrajectoryQueue
    from repro.distributed.transport import (TRANSPORTS, InprocTransport,
                                             ShmTransport, Transport,
                                             make_transport)
