"""Aggregate results/dryrun/*.json into the §Roofline markdown table.

  PYTHONPATH=src python -m repro.roofline.table [--dir results/dryrun]
      [--mesh 16x16] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

ARCH_ORDER = [
    "recurrentgemma-2b", "granite-moe-1b-a400m", "whisper-small",
    "mamba2-1.3b", "stablelm-1.6b", "gemma-7b", "qwen1.5-4b",
    "llama-3.2-vision-11b", "mistral-nemo-12b", "olmoe-1b-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_: str, mesh: str, rules: str = "baseline") -> List[Dict]:
    pod = "pod2" if mesh.startswith("2x") else "pod1"
    out = []
    for f in glob.glob(os.path.join(dir_, f"*_{pod}_{rules}.json")):
        d = json.load(open(f))
        if d.get("rules") == rules:
            out.append(d)
    key = {(a, s): (i, j) for i, a in enumerate(ARCH_ORDER)
           for j, s in enumerate(SHAPE_ORDER)}
    out.sort(key=lambda d: key.get((d["arch"], d["shape"]), (99, 99)))
    return out


def fmt_ms(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    return f"{s*1e3:.1f}ms"


def render(records: List[Dict], markdown: bool = True) -> str:
    lines = []
    hdr = ("| arch | shape | status | compute | memory | collective | "
           "bottleneck | useful/HLO | mem-model/dev | fits |")
    sep = "|" + "---|" * 10
    lines.append(hdr)
    lines.append(sep)
    for d in records:
        if d["status"] == "skip":
            lines.append(f"| {d['arch']} | {d['shape']} | SKIP "
                         f"({d['reason'][:40]}…) | | | | | | | |")
            continue
        if d["status"] != "ok":
            lines.append(f"| {d['arch']} | {d['shape']} | ERROR | | | | | | | |")
            continue
        r = d["roofline"]
        mm = d.get("memory_model", {})
        lines.append(
            f"| {d['arch']} | {d['shape']} | ok | {fmt_ms(r['compute_s'])} "
            f"| {fmt_ms(r['memory_s'])} | {fmt_ms(r['collective_s'])} "
            f"| **{r['bottleneck']}** "
            f"| {r.get('useful_flops_ratio', 0) or 0:.2f} "
            f"| {mm.get('total', 0)/1e9:.1f}GB "
            f"| {'Y' if mm.get('fits_16g') else 'N'} |")
    return "\n".join(lines)


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="results/dryrun")
    p.add_argument("--mesh", default="16x16")
    p.add_argument("--rules", default="baseline")
    args = p.parse_args()
    records = load(args.dir, args.mesh, args.rules)
    print(f"### Roofline — mesh {args.mesh}, rules {args.rules} "
          f"({len(records)} pairs)\n")
    print(render(records))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
