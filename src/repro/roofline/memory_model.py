"""Analytic per-device TPU memory model for the dry-run "fits" proof.

``compiled.memory_analysis().temp_size_in_bytes`` on the CPU backend is an
upper bound under the CPU thunk scheduler (which schedules for parallelism,
not liveness, and keeps many per-layer transients nominally live — we
measured remat-on == remat-off temp on CPU). A TPU buffer assignment
reuses sequential layers' buffers, so we additionally report this analytic
model, which is what the per-device HBM actually has to hold:

  persistent: param shards (f32) + optimizer state + (train) grad shards
  activations (train): checkpointed block inputs (one (B_loc, T, d) bf16
    per layer group) + the working set of ONE block's fwd+bwd
  caches (decode/prefill): KV/state shards
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.configs.base import ArchConfig, InputShape
from repro.models import backbone as bb
from repro.models import common
from repro.models import transformer as tfm
from repro.sharding.rules import Rules


def _shard_bytes(specs, rules: Rules) -> int:
    total = 0
    for s in jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, common.Spec)):
        spec = rules.spec(s.logical, s.shape)
        size = int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
        denom = 1
        for dim, p in enumerate(spec):
            if p is None:
                continue
            axes = (p,) if isinstance(p, str) else p
            for a in axes:
                denom *= rules.mesh.shape[a]
        total += size // denom
    return total


def _batch_shards(rules: Rules) -> int:
    n = 1
    ax = rules.table.get("batch")
    if ax:
        axes = (ax,) if isinstance(ax, str) else ax
        for a in axes:
            n *= rules.mesh.shape[a]
    return n


def _model_shards(rules: Rules) -> int:
    out = 1
    for name, size in rules.mesh.shape.items():
        if name not in ("data", "pod"):
            out *= size
    return out


def estimate(arch: ArchConfig, shape: InputShape, rules: Rules,
             num_actions: int = 18) -> Dict[str, float]:
    specs = bb.backbone_specs(arch, num_actions)
    p_bytes = _shard_bytes(specs, rules)            # f32 params per device
    b_loc = max(shape.global_batch // _batch_shards(rules), 1)
    d = arch.d_model
    act = 2  # bf16
    out: Dict[str, float] = {"params": float(p_bytes)}

    if shape.kind == "train":
        t = shape.seq_len
        out["opt_state"] = float(p_bytes)           # rmsprop ms
        out["grads"] = float(p_bytes)
        n_blocks = arch.num_layers
        # checkpointed residuals: block input per layer
        out["residuals"] = float(n_blocks * b_loc * t * d * act)
        # one block's live working set (dominated by attention scores f32
        # chunk or MoE dispatch buffers), sharded over model where possible
        h_loc = max(arch.num_heads // _model_shards(rules), 1) \
            if arch.num_heads else 1
        qc = min(t, 4096)
        attn_ws = b_loc * h_loc * qc * min(t, 4096) * 4
        ff_loc = max(arch.d_ff // _model_shards(rules), arch.d_ff and 1)
        mlp_ws = b_loc * t * max(ff_loc, d) * act * 3
        moe_ws = 0
        if arch.moe is not None:
            cap = int(b_loc * t * arch.moe.num_experts_per_tok /
                      arch.moe.num_experts * 1.25)
            e_loc = max(arch.moe.num_experts // _model_shards(rules), 1)
            moe_ws = (e_loc * cap * max(arch.d_ff, d) * act * 3 +
                      b_loc * t * arch.moe.num_experts_per_tok * d * act)
        out["block_workspace"] = float(max(attn_ws + mlp_ws, moe_ws) * 2)
    else:
        # prefill/decode: cache + one block workspace
        cache_abs = bb.cache_abstract(
            shape.global_batch,
            min(arch.sliding_window or shape.seq_len, shape.seq_len), arch)
        axes = bb.cache_logical_axes(arch)
        cache_bytes = 0
        for sd, ax in zip(
                jax.tree.leaves(cache_abs,
                                is_leaf=lambda x: isinstance(
                                    x, jax.ShapeDtypeStruct)),
                jax.tree.leaves(axes,
                                is_leaf=lambda x: isinstance(x, tuple))):
            spec = rules.spec(ax, sd.shape)
            size = int(np.prod(sd.shape)) * sd.dtype.itemsize
            denom = 1
            for p in spec:
                if p is None:
                    continue
                for a in ((p,) if isinstance(p, str) else p):
                    denom *= rules.mesh.shape[a]
            cache_bytes += size // denom
        out["cache"] = float(cache_bytes)
        t = shape.seq_len if shape.kind == "prefill" else 1
        out["block_workspace"] = float(b_loc * t * max(arch.d_ff, d) * act * 3)

    out["total"] = float(sum(out.values()))
    out["fits_16g"] = bool(out["total"] < 16e9)
    return out
