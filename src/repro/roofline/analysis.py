"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), in seconds-per-step:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

``cost_analysis()`` on an SPMD-compiled executable reports per-device
FLOPs/bytes. Collective bytes are not in cost_analysis: we parse the
compiled HLO and sum the *result* shapes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (result-size is the
per-device data moved to first order; all-gather results count the full
gathered size, which upper-bounds (n-1)/n ingress).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes summed over the module.

    '-done' ops are skipped (their '-start' counterpart carries the shape
    in async pairs; counting both would double)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _LINE_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        if "-done(" in m.group(0):
            continue
        out[kind] += _shape_bytes(type_str)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None
    useful_flops_ratio: Optional[float] = None


def executable_cost(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalised across jax versions: older
    releases return a one-element list of dicts, newer ones the dict
    itself."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def analyse(cost: Dict[str, float], hlo_text: str, hw: Dict[str, float],
            model_flops: Optional[float] = None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    coll_total = float(sum(coll.values()))
    compute_s = flops / hw["peak_flops_bf16"]
    memory_s = byts / hw["hbm_bw"]
    collective_s = coll_total / hw["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    ratio = None
    if model_flops:
        # model_flops is whole-step; cost flops are per-device
        ratio = model_flops / max(flops, 1.0)
    return Roofline(flops, byts, coll_total, coll, compute_s, memory_s,
                    collective_s, bottleneck, model_flops, ratio)


# ---------------------------------------------------------------------------
# MODEL_FLOPS: 6*N*D for training (dense), 6*N_active*D (MoE); 2*N per
# decoded token.


def _active_params(arch, n_params: int) -> int:
    if arch.moe is None:
        return n_params
    m = arch.moe
    # expert FFN params scale down by (top_k / E); router+attn+embed stay
    gated = arch.activation in ("geglu", "swiglu")
    per_expert = arch.d_model * arch.d_ff * (3 if gated else 2)
    expert_params = arch.num_layers * m.num_experts * per_expert
    active_expert = expert_params * m.num_experts_per_tok / m.num_experts
    return n_params - expert_params + int(active_expert)


def model_flops(arch, n_params: int, shape, per_device: bool,
                n_devices: int) -> float:
    n_active = _active_params(arch, n_params)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_devices if per_device else total
