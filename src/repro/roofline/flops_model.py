"""Analytic, *mesh-aware* per-device FLOPs/bytes model per (arch x shape).

Why this exists:
  * XLA's ``cost_analysis()`` counts a while-loop body once regardless of
    trip count, so inner chunk scans (SSD, RG-LRU, chunked long-context
    attention) are invisible to it.
  * GSPMD replicates any op whose parallel dim is not divisible by the
    model axis (e.g. whisper's 12 heads or recurrentgemma's 10 heads on a
    16-way model axis): per-device FLOPs are then NOT total/M. The model
    accounts for that replication explicitly — the dry-run HLO numbers
    cross-validate it for shapes without inner scans.

Conventions: every matmul in the implementation is accounted with its
actual shapes (capacity-padded MoE, masked-dense causal attention).
fwd = 1x; train = 4x fwd (backward 2x + remat recompute 1x). Bytes count
operand+result traffic per op, bf16 activations, f32 scores.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.configs.base import ArchConfig, InputShape

TRAIN_MULT = 4.0  # fwd + bwd(2x) + remat recompute(1x)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def mm(self, m: float, k: float, n: float, batch: float = 1.0,
           dt_in: int = 2, dt_out: int = 2, shards: int = 1) -> "Cost":
        self.flops += 2.0 * m * k * n * batch / shards
        self.bytes += batch * (m * k * dt_in + k * n * dt_in +
                               m * n * dt_out) / shards
        return self

    def ew(self, n_elems: float, reads: int = 2, writes: int = 1,
           dt: int = 2, flops_per: float = 1.0, shards: int = 1) -> "Cost":
        self.flops += n_elems * flops_per / shards
        self.bytes += n_elems * (reads + writes) * dt / shards
        return self

    def add(self, other: "Cost") -> "Cost":
        self.flops += other.flops
        self.bytes += other.bytes
        return self

    def scale(self, f: float) -> "Cost":
        self.flops *= f
        self.bytes *= f
        return self


def _div(dim: int, m: int) -> int:
    """Shard count on the model axis for a dim: m if divisible else 1
    (GSPMD replication fallback — same rule as sharding/rules.py)."""
    return m if dim and dim % m == 0 else 1


def _attention_layer(arch: ArchConfig, b: float, s_q: int, s_kv: int, m: int,
                     window: int = 0, cross: bool = False,
                     kv_proj: bool = True) -> Cost:
    c = Cost()
    d, h, kh = arch.d_model, arch.num_heads, arch.num_kv_heads
    dh = arch.resolved_head_dim
    mh = _div(h, m)
    mkh = _div(kh, m)
    c.mm(s_q, d, h * dh, batch=b, shards=mh)               # q proj
    kv_tokens = s_kv if cross else s_q
    if kv_proj:
        c.mm(kv_tokens, d, 2 * kh * dh, batch=b, shards=mkh)  # k, v proj
    c.mm(s_q, h * dh, d, batch=b, shards=mh)               # o proj
    w = min(window or s_kv, s_kv)
    c.mm(s_q, dh, w, batch=b * h, dt_out=4, shards=mh)     # qk^T (f32)
    c.ew(b * h * s_q * w, reads=1, writes=1, dt=4, flops_per=5, shards=mh)
    c.mm(s_q, w, dh, batch=b * h, shards=mh)               # pv
    return c


def _mlp_layer(arch: ArchConfig, b: float, s: int, m: int) -> Cost:
    c = Cost()
    d, ff = arch.d_model, arch.d_ff
    mf = _div(ff, m)
    gated = arch.activation in ("geglu", "swiglu")
    c.mm(s, d, ff, batch=b * (2 if gated else 1), shards=mf)
    c.mm(s, ff, d, batch=b, shards=mf)
    return c


def _moe_layer(arch: ArchConfig, b: float, s: int, m: int) -> Cost:
    c = Cost()
    mo = arch.moe
    d = arch.d_model
    me = _div(mo.num_experts, m)
    c.mm(s, d, mo.num_experts, batch=b, dt_out=4)          # router (repl.)
    eff = b * s * mo.num_experts_per_tok * mo.capacity_factor
    gated = arch.activation in ("geglu", "swiglu")
    c.mm(eff, d, arch.d_ff, batch=(2 if gated else 1), shards=me)
    c.mm(eff, arch.d_ff, d, shards=me)
    # dispatch bookkeeping (cumsum/one-hot/scatter) runs on every shard
    c.ew(b * s * mo.num_experts_per_tok * d * 2, reads=1, writes=1)
    return c


def _ssm_layer(arch: ArchConfig, b: float, s: int, m: int) -> Cost:
    c = Cost()
    ss = arch.ssm
    d = arch.d_model
    di = ss.expand * d
    h = di // ss.head_dim
    p, n = ss.head_dim, ss.state_dim
    mh = _div(h, m)
    l = min(ss.chunk_size, s)
    nc = max(s // l, 1)
    c.mm(s, d, 2 * di + 2 * n + h, batch=b, shards=_div(di, m))
    c.ew(b * s * (di + 2 * n) * ss.conv_width, shards=_div(di, m))
    c.mm(l, n, l, batch=b * nc, dt_out=4)                  # C.B (h-independent)
    c.flops += 2.0 * b * nc * l * l * h * p / mh           # y_intra
    c.bytes += b * nc * (l * l * h * 4 + l * h * p * 4) / mh
    c.flops += 4.0 * b * s * h * p * n / mh                # inter + state
    c.bytes += b * nc * h * p * n * 4 * 3 / mh
    c.mm(s, di, d, batch=b, shards=_div(di, m))
    return c


def _rglru_layer(arch: ArchConfig, b: float, s: int, m: int) -> Cost:
    c = Cost()
    d = arch.d_model
    w = arch.rglru.lru_width or d
    mw = _div(w, m)
    c.mm(s, d, 2 * w, batch=b, shards=mw)
    c.ew(b * s * w * arch.rglru.conv_width, shards=mw)
    # gates contract over the (sharded) w input dim -> compute shards by mw
    c.mm(s, w, 2 * w, batch=b, dt_in=4, dt_out=4, shards=mw)
    c.ew(b * s * w, reads=3, writes=2, dt=4, flops_per=8, shards=mw)
    c.mm(s, w, d, batch=b, shards=mw)
    return c


def _layer_counts(arch: ArchConfig) -> Dict[str, int]:
    from repro.models.transformer import layer_plan, num_groups
    group, leftover = layer_plan(arch)
    kinds = group * num_groups(arch) + leftover
    out: Dict[str, int] = {}
    for k in kinds:
        out[k] = out.get(k, 0) + 1
    return out


def forward_cost(arch: ArchConfig, b: float, s: int, kv_len: int, m: int,
                 num_actions: int = 18, decode: bool = False) -> Cost:
    """One forward over b (per-device) sequences of s new tokens attending
    to kv_len context; m = model-axis size."""
    total = Cost()
    counts = _layer_counts(arch)
    for kind, n in counts.items():
        if kind in ("attn", "moe"):
            c = _attention_layer(arch, b, s, kv_len, m)
            c.add(_moe_layer(arch, b, s, m) if kind == "moe"
                  else _mlp_layer(arch, b, s, m))
        elif kind == "local":
            window = (arch.rglru.attention_window if arch.rglru
                      else arch.sliding_window)
            c = _attention_layer(arch, b, s, kv_len, m, window=window)
            c.add(_mlp_layer(arch, b, s, m))
        elif kind == "recurrent":
            c = _rglru_layer(arch, b, s, m)
            c.add(_mlp_layer(arch, b, s, m))
        elif kind == "ssm":
            if decode:
                ss = arch.ssm
                di = ss.expand * arch.d_model
                h = di // ss.head_dim
                mh = _div(h, m)
                c = Cost()
                c.mm(s, arch.d_model, 2 * di + 2 * ss.state_dim + h,
                     batch=b, shards=_div(di, m))
                c.flops += 4.0 * b * h * ss.head_dim * ss.state_dim / mh
                c.bytes += b * h * ss.head_dim * ss.state_dim * 4 * 3 / mh
                c.mm(s, di, arch.d_model, batch=b, shards=_div(di, m))
            else:
                c = _ssm_layer(arch, b, s, m)
        elif kind == "cross":
            c = _attention_layer(arch, b, s, arch.encoder_seq_len, m,
                                 cross=True, kv_proj=not decode)
            c.add(_mlp_layer(arch, b, s, m))
        elif kind == "enc_dec":
            c = _attention_layer(arch, b, s, kv_len, m)
            c.add(_attention_layer(arch, b, s, arch.encoder_seq_len, m,
                                   cross=True, kv_proj=not decode))
            c.add(_mlp_layer(arch, b, s, m))
        else:
            raise ValueError(kind)
        total.add(c.scale(n))
    # decode reads cached encoder projections; the encoder itself ran at
    # prefill time
    if arch.encoder_layers and not decode:
        enc = Cost()
        enc.add(_attention_layer(arch, b, arch.encoder_seq_len,
                                 arch.encoder_seq_len, m))
        enc.add(_mlp_layer(arch, b, arch.encoder_seq_len, m))
        total.add(enc.scale(arch.encoder_layers))
    total.ew(b * s * arch.d_model, reads=1, writes=1)      # embed gather
    total.mm(s, arch.d_model, num_actions + 1, batch=b, dt_out=4)
    return total


def step_cost(arch: ArchConfig, shape: InputShape, n_devices: int,
              model_axis: int = 16) -> Tuple[float, float]:
    """(flops, bytes) per device for the step this shape lowers."""
    b, s = shape.global_batch, shape.seq_len
    data_shards = n_devices // model_axis
    b_loc = max(b / data_shards, 1.0)   # < data_shards batch => replication
    m = model_axis
    if shape.kind == "train":
        c = forward_cost(arch, b_loc, s, s, m).scale(TRAIN_MULT)
    elif shape.kind == "prefill":
        c = forward_cost(arch, b_loc, s, s, m)
    else:
        kv = s if arch.family == "ssm" else min(arch.sliding_window or s, s)
        c = forward_cost(arch, b_loc, 1, kv, m, decode=True)
        if arch.family != "ssm":
            dh = arch.resolved_head_dim
            counts = _layer_counts(arch)
            n_attn = sum(v for k, v in counts.items()
                         if k in ("attn", "local", "moe", "enc_dec"))
            window = arch.rglru.attention_window if arch.rglru else \
                (arch.sliding_window or s)
            mkh = _div(arch.num_kv_heads, m)
            c.bytes += (n_attn * b_loc * min(window, s) *
                        arch.num_kv_heads * dh * 2 * 2) / mkh
    return c.flops, c.bytes
