"""Checkpointing: param/opt-state pytrees -> .npz + JSON treedef.

No orbax on this box; this writes a flat npz of leaves plus a structure
manifest, supports atomic save (tmp + rename), latest-symlink, and
restores onto an existing abstract structure (so restored leaves can be
device_put with the right shardings by the caller).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(directory: str, step: int, tree: PyTree,
         extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "keys": sorted(leaves),
                "extra": extra or {}}
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    os.close(fd)
    np.savez(tmp, **leaves)
    # np.savez appends .npz to names without the suffix, leaving the
    # original mkstemp file behind — move the real archive, drop the stub
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    if os.path.exists(tmp):
        os.remove(tmp)
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(directory, "LATEST"), "w") as f:
        f.write(str(step))
    return path


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def read_manifest(directory: str,
                  step: Optional[int] = None) -> Dict[str, Any]:
    """The JSON manifest of a checkpoint — including the ``extra`` dict
    ``save`` wrote (group version / restart epochs ride there)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    with open(os.path.join(directory, f"ckpt_{step:08d}.json")) as f:
        return json.load(f)


def load_with_extra(directory: str, step: Optional[int] = None
                    ) -> Tuple[PyTree, int, Dict[str, Any]]:
    """Restore WITHOUT a ``like`` structure: rebuilds a nested dict
    tree from the path-keyed leaves. Every tree this repo checkpoints
    (params, optimizer state, the combined ``{"params":..., "opt":...}``
    fleet checkpoint) is nested dicts of arrays, so the path keys ARE
    the structure. Returns ``(tree, step, extra)``."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    manifest = read_manifest(directory, step)
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    tree: Dict[str, Any] = {}
    for key in manifest["keys"]:
        node = tree
        parts = key.split("/")
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = data[key]
    return tree, step, manifest.get("extra", {})


def restore(directory: str, like: PyTree,
            step: Optional[int] = None) -> Tuple[PyTree, int]:
    """Restore into the structure of ``like`` (values ignored)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    flat = jax.tree_util.tree_flatten_with_path(like)
    paths, treedef = flat
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        assert arr.shape == tuple(np.shape(leaf)), (key, arr.shape,
                                                    np.shape(leaf))
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(jax.tree.structure(like), leaves)
    return tree, step
