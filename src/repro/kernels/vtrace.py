"""Pallas TPU kernel for the V-trace reverse scan (paper Eq. 1 / Remark 1).

The recurrence is inherently sequential in time, so the kernel puts the
batch on lanes and iterates *time chunks in reverse* as sequential TPU
grid steps, carrying ``acc_{s+1} = v_{s+1} - V(x_{s+1})`` in a VMEM
scratch accumulator across grid steps — the TPU-idiomatic analogue of the
paper's fused-recurrence optimisation (§3.1).

Layout: all tensors time-major (T, B) float32. Grid = (B blocks, reversed
T chunks); T chunks iterate fastest so each batch block completes its full
reverse sweep before the next begins. One fused pass emits both the
targets v_s and the policy-gradient advantages.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_T_CHUNK = 256
DEFAULT_B_BLOCK = 128

INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Decide whether a Pallas kernel runs interpreted or compiled.

    Resolution order: explicit caller argument (``True``/``False``) >
    ``REPRO_PALLAS_INTERPRET`` env override ("1"/"0") > backend
    auto-detect — the real kernel on TPU, the interpreter everywhere
    else (CPU has no Mosaic lowering). The env override exists so a TPU
    run can be flipped to interpret mode for debugging (and a test rig
    can pin either mode) without touching call sites.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(INTERPRET_ENV)
    if env is not None and env != "":
        return env != "0"
    return jax.default_backend() != "tpu"


def _vtrace_kernel(rho_ref, c_ref, disc_ref, rew_ref, v_ref, vtp1_ref,
                   vs_ref, pg_ref, acc_ref, *, t_chunk: int):
    tj = pl.program_id(1)

    @pl.when(tj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(i, acc):
        s = t_chunk - 1 - i
        rho = rho_ref[s, :]
        disc = disc_ref[s, :]
        rew = rew_ref[s, :]
        v = v_ref[s, :]
        vtp1 = vtp1_ref[s, :]
        pg_ref[s, :] = rho * (rew + disc * (vtp1 + acc) - v)
        delta = rho * (rew + disc * vtp1 - v)
        acc = delta + disc * c_ref[s, :] * acc
        vs_ref[s, :] = v + acc
        return acc

    acc = jax.lax.fori_loop(0, t_chunk, body, acc_ref[0, :])
    acc_ref[0, :] = acc


def vtrace_pallas(rho, c, discounts, rewards, values, values_tp1,
                  t_chunk: int = DEFAULT_T_CHUNK,
                  b_block: int = DEFAULT_B_BLOCK,
                  interpret: Optional[bool] = None):
    """All inputs (T, B) float32. Returns (vs, pg_adv), each (T, B).

    ``interpret=None`` (the default) auto-detects: compiled kernel on
    TPU, interpreter fallback elsewhere; see ``resolve_interpret``.
    """
    interpret = resolve_interpret(interpret)
    t, b = rho.shape
    t_chunk = min(t_chunk, t)
    b_block = min(b_block, b)
    # pad to multiples
    tp = (-t) % t_chunk
    bp = (-b) % b_block
    args = (rho, c, discounts, rewards, values, values_tp1)
    if tp or bp:
        args = tuple(jnp.pad(x, ((0, tp), (0, bp))) for x in args)
    tt, bb = t + tp, b + bp
    nt, nb = tt // t_chunk, bb // b_block

    in_spec = pl.BlockSpec((t_chunk, b_block),
                           lambda i, j: (nt - 1 - j, i))
    out_spec = pl.BlockSpec((t_chunk, b_block),
                            lambda i, j: (nt - 1 - j, i))
    vs, pg = pl.pallas_call(
        functools.partial(_vtrace_kernel, t_chunk=t_chunk),
        grid=(nb, nt),
        in_specs=[in_spec] * 6,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((tt, bb), jnp.float32)] * 2,
        scratch_shapes=[pltpu.VMEM((1, b_block), jnp.float32)],
        interpret=interpret,
    )(*args)
    return vs[:t, :b], pg[:t, :b]
