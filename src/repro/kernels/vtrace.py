"""Pallas TPU kernel for the V-trace reverse scan (paper Eq. 1 / Remark 1).

The recurrence is inherently sequential in time, so the kernel puts the
batch on lanes and iterates *time chunks in reverse* as sequential TPU
grid steps, carrying ``acc_{s+1} = v_{s+1} - V(x_{s+1})`` in a VMEM
scratch accumulator across grid steps — the TPU-idiomatic analogue of the
paper's fused-recurrence optimisation (§3.1).

Layout: all tensors time-major (T, B) float32. Grid = (B blocks, reversed
T chunks); T chunks iterate fastest so each batch block completes its full
reverse sweep before the next begins. One fused pass emits both the
targets v_s and the policy-gradient advantages.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_T_CHUNK = 256
DEFAULT_B_BLOCK = 128

INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Decide whether a Pallas kernel runs interpreted or compiled.

    Resolution order: explicit caller argument (``True``/``False``) >
    ``REPRO_PALLAS_INTERPRET`` env override ("1"/"0") > backend
    auto-detect — the real kernel on TPU, the interpreter everywhere
    else (CPU has no Mosaic lowering). The env override exists so a TPU
    run can be flipped to interpret mode for debugging (and a test rig
    can pin either mode) without touching call sites.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get(INTERPRET_ENV)
    if env is not None and env != "":
        return env != "0"
    return jax.default_backend() != "tpu"


def _vtrace_kernel(rho_ref, c_ref, disc_ref, rew_ref, v_ref, vtp1_ref,
                   vs_ref, pg_ref, acc_ref, *, t_chunk: int):
    tj = pl.program_id(1)

    @pl.when(tj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(i, acc):
        s = t_chunk - 1 - i
        rho = rho_ref[s, :]
        disc = disc_ref[s, :]
        rew = rew_ref[s, :]
        v = v_ref[s, :]
        vtp1 = vtp1_ref[s, :]
        pg_ref[s, :] = rho * (rew + disc * (vtp1 + acc) - v)
        delta = rho * (rew + disc * vtp1 - v)
        acc = delta + disc * c_ref[s, :] * acc
        vs_ref[s, :] = v + acc
        return acc

    acc = jax.lax.fori_loop(0, t_chunk, body, acc_ref[0, :])
    acc_ref[0, :] = acc


def vtrace_pallas(rho, c, discounts, rewards, values, values_tp1,
                  t_chunk: int = DEFAULT_T_CHUNK,
                  b_block: int = DEFAULT_B_BLOCK,
                  interpret: Optional[bool] = None):
    """All inputs (T, B) float32. Returns (vs, pg_adv), each (T, B).

    ``interpret=None`` (the default) auto-detects: compiled kernel on
    TPU, interpreter fallback elsewhere; see ``resolve_interpret``.
    """
    interpret = resolve_interpret(interpret)
    t, b = rho.shape
    t_chunk = min(t_chunk, t)
    b_block = min(b_block, b)
    # pad to multiples
    tp = (-t) % t_chunk
    bp = (-b) % b_block
    args = (rho, c, discounts, rewards, values, values_tp1)
    if tp or bp:
        args = tuple(jnp.pad(x, ((0, tp), (0, bp))) for x in args)
    tt, bb = t + tp, b + bp
    nt, nb = tt // t_chunk, bb // b_block

    in_spec = pl.BlockSpec((t_chunk, b_block),
                           lambda i, j: (nt - 1 - j, i))
    out_spec = pl.BlockSpec((t_chunk, b_block),
                            lambda i, j: (nt - 1 - j, i))
    vs, pg = pl.pallas_call(
        functools.partial(_vtrace_kernel, t_chunk=t_chunk),
        grid=(nb, nt),
        in_specs=[in_spec] * 6,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((tt, bb), jnp.float32)] * 2,
        scratch_shapes=[pltpu.VMEM((1, b_block), jnp.float32)],
        interpret=interpret,
    )(*args)
    return vs[:t, :b], pg[:t, :b]


# ---------------------------------------------------------------------------
# fused loss + V-trace: one kernel launch computes everything the IMPALA
# loss needs between the logits and the final reductions — log-softmax,
# target log-probs, entropy terms, the clipped importance weights, and
# the V-trace reverse scan — instead of ~10 separate XLA ops feeding the
# recurrence. Same layout discipline as ``vtrace_pallas`` (time-major,
# batch on lanes, reversed T chunks with a VMEM-carried accumulator);
# the action dimension rides whole in each block, padded to the 128-wide
# lane multiple with a large negative logit so softmax ignores the pad.

_NEG_PAD = -1e30     # pad logit: exp underflows to exactly 0 in f32
LANE = 128


def _loss_vtrace_kernel(logits_ref, onehot_ref, blp_ref, disc_ref,
                        rew_ref, v_ref, vtp1_ref,
                        tlp_ref, ne_ref, vs_ref, pg_ref, acc_ref, *,
                        t_chunk: int, rho_bar, c_bar, lambda_: float):
    tj = pl.program_id(1)

    @pl.when(tj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(i, acc):
        s = t_chunk - 1 - i
        row = logits_ref[s, :, :]                    # (b_block, A)
        m = jnp.max(row, axis=-1, keepdims=True)
        logp = row - m - jnp.log(
            jnp.sum(jnp.exp(row - m), axis=-1, keepdims=True))
        tlp = jnp.sum(logp * onehot_ref[s, :, :], axis=-1)
        p = jnp.exp(logp)
        tlp_ref[s, :] = tlp
        ne_ref[s, :] = jnp.sum(p * logp, axis=-1)
        rho_raw = jnp.exp(tlp - blp_ref[s, :])
        rho = (jnp.minimum(rho_bar, rho_raw)
               if rho_bar is not None else rho_raw)
        c = lambda_ * (jnp.minimum(c_bar, rho_raw)
                       if c_bar is not None else rho_raw)
        disc = disc_ref[s, :]
        rew = rew_ref[s, :]
        v = v_ref[s, :]
        vtp1 = vtp1_ref[s, :]
        pg_ref[s, :] = rho * (rew + disc * (vtp1 + acc) - v)
        delta = rho * (rew + disc * vtp1 - v)
        acc = delta + disc * c * acc
        vs_ref[s, :] = v + acc
        return acc

    acc = jax.lax.fori_loop(0, t_chunk, body, acc_ref[0, :])
    acc_ref[0, :] = acc


def loss_vtrace_pallas(logits, onehot, behaviour_logprob, discounts,
                       rewards, values, values_tp1,
                       rho_bar=1.0, c_bar=1.0, lambda_: float = 1.0,
                       t_chunk: int = DEFAULT_T_CHUNK,
                       b_block: int = DEFAULT_B_BLOCK,
                       interpret: Optional[bool] = None):
    """Forward-only fused pass. ``logits``/``onehot`` are (T, B, A)
    float32, everything else (T, B) float32. Returns
    (target_logprob, neg_entropy, vs, pg_adv), each (T, B).

    The onehot action encoding is an *input* (rather than int actions)
    so every argument of the differentiable wrapper is a float tensor —
    and so the in-kernel gather is a lane-friendly multiply-reduce."""
    interpret = resolve_interpret(interpret)
    t, b = behaviour_logprob.shape
    a = logits.shape[-1]
    t_chunk = min(t_chunk, t)
    b_block = min(b_block, b)
    tp = (-t) % t_chunk
    bp = (-b) % b_block
    ap = (-a) % LANE
    flat = (behaviour_logprob, discounts, rewards, values, values_tp1)
    if tp or bp:
        flat = tuple(jnp.pad(x, ((0, tp), (0, bp))) for x in flat)
    if tp or bp or ap:
        # pad rows get uniform log-probs over real lanes (tlp = onehot
        # sum = 0 against a zero onehot), zero rewards/discounts/values:
        # the carried accumulator stays exactly zero through them
        logits = jnp.pad(logits, ((0, tp), (0, bp), (0, ap)),
                         constant_values=_NEG_PAD)
        onehot = jnp.pad(onehot, ((0, tp), (0, bp), (0, ap)))
    tt, bb, aa = t + tp, b + bp, a + ap
    nt, nb = tt // t_chunk, bb // b_block

    spec2d = pl.BlockSpec((t_chunk, b_block), lambda i, j: (nt - 1 - j, i))
    spec3d = pl.BlockSpec((t_chunk, b_block, aa),
                          lambda i, j: (nt - 1 - j, i, 0))
    tlp, ne, vs, pg = pl.pallas_call(
        functools.partial(_loss_vtrace_kernel, t_chunk=t_chunk,
                          rho_bar=rho_bar, c_bar=c_bar, lambda_=lambda_),
        grid=(nb, nt),
        in_specs=[spec3d, spec3d] + [spec2d] * 5,
        out_specs=[spec2d] * 4,
        out_shape=[jax.ShapeDtypeStruct((tt, bb), jnp.float32)] * 4,
        scratch_shapes=[pltpu.VMEM((1, b_block), jnp.float32)],
        interpret=interpret,
    )(logits, onehot, *flat)
    return tuple(x[:t, :b] for x in (tlp, ne, vs, pg))


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9))
def fused_loss_vtrace(logits, onehot, behaviour_logprob, discounts,
                      rewards, values, values_tp1, rho_bar=1.0,
                      c_bar=1.0, lambda_: float = 1.0):
    """Differentiable wrapper over ``loss_vtrace_pallas``.

    Gradients flow ONLY into ``logits`` (through the target log-probs
    and the entropy terms, both closed-form — no scan in the backward);
    ``vs``/``pg_adv`` are V-trace *targets* and implicitly
    stop-gradient, exactly like the scan implementation's contract."""
    return loss_vtrace_pallas(logits, onehot, behaviour_logprob,
                              discounts, rewards, values, values_tp1,
                              rho_bar=rho_bar, c_bar=c_bar,
                              lambda_=lambda_)


def _fused_fwd(logits, onehot, behaviour_logprob, discounts, rewards,
               values, values_tp1, rho_bar, c_bar, lambda_):
    outs = fused_loss_vtrace(logits, onehot, behaviour_logprob,
                             discounts, rewards, values, values_tp1,
                             rho_bar, c_bar, lambda_)
    tlp, ne, vs, pg = outs
    return outs, (logits, onehot, ne)


def _fused_bwd(rho_bar, c_bar, lambda_, res, cts):
    logits, onehot, ne = res
    g_tlp, g_ne, _g_vs, _g_pg = cts       # vs/pg_adv: stop-gradient
    logp = jax.nn.log_softmax(logits, axis=-1)
    p = jnp.exp(logp)
    # d tlp / d logits = onehot - p ;  d ne / d logits = p (logp - ne)
    d_logits = (g_tlp[..., None] * (onehot - p) +
                g_ne[..., None] * p * (logp - ne[..., None]))
    zeros_tb = jnp.zeros_like(ne)
    return (d_logits, jnp.zeros_like(onehot), zeros_tb, zeros_tb,
            zeros_tb, zeros_tb, zeros_tb)


fused_loss_vtrace.defvjp(_fused_fwd, _fused_bwd)
