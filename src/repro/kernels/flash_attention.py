"""Pallas TPU flash-attention forward (prefill/training attention).

GQA-aware causal attention with optional sliding window — the compute hot
spot of ``prefill_32k``. Grid = (B, H, q blocks, kv blocks); kv blocks
iterate fastest with the online-softmax running state (m, l, acc) in VMEM
scratch. Fully-masked kv blocks (beyond the causal frontier or outside
the window) are skipped with ``pl.when``, so causal work is ~S^2/2 and
windowed work is O(S*W) — unlike the masked-dense jnp path, nothing is
computed then thrown away.

Layout: q (B, T, H, D); k/v (B, S, K, D); blocks (q_blk, D) x (kv_blk, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_Q_BLOCK = 256
DEFAULT_KV_BLOCK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, q_blk: int, kv_blk: int, causal: bool,
                  window: int, t: int, s: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * q_blk
    kv_start = ki * kv_blk
    # block-level skip: kv block entirely after the causal frontier, or
    # entirely before the window
    live = jnp.bool_(True)
    if causal:
        live &= kv_start <= q_start + q_blk - 1
    if window:
        live &= kv_start + kv_blk - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :]                  # (q_blk, D)
        k = k_ref[0, :, 0, :]                  # (kv_blk, D)
        v = v_ref[0, :, 0, :]
        sc = jnp.dot(q.astype(jnp.float32),
                     k.astype(jnp.float32).T) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (q_blk, kv_blk), 0)
        k_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_blk, kv_blk), 1)
        mask = k_pos < s                        # padded keys
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        sc = jnp.where(mask, sc, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * corr[:, None] +
                        jnp.dot(p, v.astype(jnp.float32)))
        m_ref[...] = m_new

    o_ref[0, :, 0, :] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)[:, None]
                         ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           q_block: int = DEFAULT_Q_BLOCK,
                           kv_block: int = DEFAULT_KV_BLOCK,
                           interpret: bool = True):
    """q: (B,T,H,D); k/v: (B,S,K,D) with H % K == 0. Returns (B,T,H,D)."""
    b, t, h, d = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = d ** -0.5
    q_block = min(q_block, t)
    kv_block = min(kv_block, s)
    tp = (-t) % q_block
    sp = (-s) % kv_block
    if tp:
        q = jnp.pad(q, ((0, 0), (0, tp), (0, 0), (0, 0)))
    if sp:
        k = jnp.pad(k, ((0, 0), (0, sp), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp), (0, 0), (0, 0)))
    nq = (t + tp) // q_block
    nk = (s + sp) // kv_block

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, q_blk=q_block,
                          kv_blk=kv_block, causal=causal, window=window,
                          t=t, s=s),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, 1, d),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, kv_block, 1, d),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, kv_block, 1, d),
                         lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, d),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t + tp, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :t]
