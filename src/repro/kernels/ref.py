"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against
(``tests/test_kernels.py`` sweeps shapes/dtypes with assert_allclose).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def vtrace_ref(rho, c, discounts, rewards, values, values_tp1
               ) -> Tuple[jax.Array, jax.Array]:
    """All inputs (T, B) float32 (time-major, matching the kernel layout).

    acc_s = delta_s + disc_s * c_s * acc_{s+1};  vs_s = v_s + acc_s
    pg_adv_s = rho_s * (r_s + disc_s * (v_tp1_s + acc_{s+1}) - v_s)
    Returns (vs, pg_adv), each (T, B).
    """
    t = rho.shape[0]
    acc = jnp.zeros_like(rho[0])
    vs = []
    pg = []
    for s in reversed(range(t)):
        pg_s = rho[s] * (rewards[s] + discounts[s] * (values_tp1[s] + acc)
                         - values[s])
        delta = rho[s] * (rewards[s] + discounts[s] * values_tp1[s] - values[s])
        acc = delta + discounts[s] * c[s] * acc
        vs.append(values[s] + acc)
        pg.append(pg_s)
    vs = jnp.stack(vs[::-1], axis=0)
    pg = jnp.stack(pg[::-1], axis=0)
    return vs, pg


def linear_scan_ref(a, b, h0: Optional[jax.Array] = None) -> jax.Array:
    """Diagonal linear recurrence h_t = a_t * h_{t-1} + b_t.

    a, b: (T, N) float32; h0: (N,) or None (zeros). Returns h (T, N).
    """
    if h0 is None:
        h0 = jnp.zeros_like(a[0])

    def body(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(body, h0, (a, b))
    return hs


def flash_attention_ref(q, k, v, causal: bool = True,
                        window: int = 0) -> jax.Array:
    """Full (masked-dense) GQA attention oracle for the flash kernel.

    q: (B,T,H,D); k/v: (B,S,K,D). Softmax in f32."""
    b, t, h, d = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, t, kh, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)


def decode_attention_ref(q, k, v, bias) -> jax.Array:
    """Single-token GQA attention against a KV cache.

    q: (B, H, D); k, v: (B, S, K, D); bias: (B, S) additive (0 or -inf).
    Returns (B, H, D). Softmax in f32.
    """
    b, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    scores = scores + bias[:, None, None, :]
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
