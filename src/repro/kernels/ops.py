"""Jit'd dispatching wrappers over the Pallas kernels.

``impl='auto'`` selects the Pallas kernel on TPU backends and the pure-jnp
reference elsewhere (this container is CPU-only, where the kernels run in
interpret mode — used for validation, not speed).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import vtrace as vtrace_k
from repro.kernels import linear_scan as linear_scan_k
from repro.kernels import decode_attention as decode_k
from repro.kernels import flash_attention as flash_k


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    # one resolution for every kernel: REPRO_PALLAS_INTERPRET override,
    # else compiled on TPU / interpreted elsewhere
    return vtrace_k.resolve_interpret(None)


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


@functools.partial(jax.jit, static_argnames=("impl",))
def vtrace(log_rhos, discounts, rewards, values, bootstrap_value,
           rho_bar: Optional[float] = 1.0, c_bar: Optional[float] = 1.0,
           lambda_: float = 1.0, impl: str = "auto"
           ) -> Tuple[jax.Array, jax.Array]:
    """Batch-major (B, T) inputs, like ``repro.core.vtrace``.

    Returns (vs, pg_advantages) each (B, T) f32.
    """
    impl_r = _resolve(impl)
    rhos = jnp.exp(log_rhos.astype(jnp.float32))
    rho = jnp.minimum(rho_bar, rhos) if rho_bar is not None else rhos
    c = lambda_ * (jnp.minimum(c_bar, rhos) if c_bar is not None else rhos)
    v = values.astype(jnp.float32)
    vtp1 = jnp.concatenate([v[:, 1:],
                            bootstrap_value.astype(jnp.float32)[:, None]], 1)
    args = tuple(x.T for x in (rho, c, discounts.astype(jnp.float32),
                               rewards.astype(jnp.float32), v, vtp1))
    if impl_r == "ref":
        vs, pg = ref.vtrace_ref(*args)
    else:
        # interpret resolution (env override > backend detect) lives in
        # the kernel, so a TPU run compiles for real by default
        vs, pg = vtrace_k.vtrace_pallas(*args)
    return vs.T, pg.T


@functools.partial(jax.jit, static_argnames=("impl",))
def linear_scan(a, b, h0=None, impl: str = "auto") -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t. a, b: (T, N) f32."""
    impl_r = _resolve(impl)
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if impl_r == "ref":
        return ref.linear_scan_ref(a, b, h0)
    return linear_scan_k.linear_scan_pallas(a, b, h0,
                                            interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("impl", "causal", "window"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    impl: str = "auto") -> jax.Array:
    """Prefill/training GQA attention. q (B,T,H,D), k/v (B,S,K,D)."""
    impl_r = _resolve(impl)
    if impl_r == "ref":
        return ref.flash_attention_ref(q, k, v, causal, window)
    return flash_k.flash_attention_pallas(q, k, v, causal=causal,
                                          window=window,
                                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("impl",))
def decode_attention(q, k, v, bias, impl: str = "auto") -> jax.Array:
    """q (B,H,D), k/v (B,S,K,D), bias (B,S) additive. Returns (B,H,D)."""
    impl_r = _resolve(impl)
    if impl_r == "ref":
        return ref.decode_attention_ref(q, k, v, bias)
    return decode_k.decode_attention_pallas(q, k, v, bias,
                                            interpret=_interpret())
