"""Pallas TPU flash-decode kernel: one query token per sequence against a
long KV cache, GQA-aware — the IMPALA actor's per-step inference hot spot
(``serve_step`` with a 32k/500k context).

Layout: q (B, K, G, D) (query heads grouped under their kv head);
k/v (B, S, K, D); additive bias (B, S) (0 valid / -inf masked).
Grid = (B, K, S chunks); S chunks iterate fastest with the online-softmax
running (max, sum, acc) state in VMEM scratch. Output is rescaled and
written on every chunk step (the final chunk's write is the result), so
no extra epilogue pass is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_S_CHUNK = 1024
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float):
    sj = pl.program_id(2)

    @pl.when(sj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                      # (G, D)
    k = k_ref[0, :, 0, :]                # (S_chunk, D)
    v = v_ref[0, :, 0, :]                # (S_chunk, D)
    bias = bias_ref[0, :]                # (S_chunk,)

    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T) * scale
    s = s + bias[None, :]                # (G, S_chunk)
    m_prev = m_ref[0]                    # (G,) stored as (1, G)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_ref[0] * corr + jnp.sum(p, axis=-1)
    acc = acc_ref[...] * corr[:, None] + jnp.dot(
        p, v.astype(jnp.float32))
    m_ref[0] = m_new
    l_ref[0] = l_new
    acc_ref[...] = acc
    o_ref[0, 0] = (acc / jnp.maximum(l_new, 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, bias, s_chunk: int = DEFAULT_S_CHUNK,
                            interpret: bool = True):
    """q: (B, H, D); k/v: (B, S, K, D); bias: (B, S). Returns (B, H, D)."""
    b, h, d = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = d ** -0.5
    s_chunk = min(s_chunk, s)
    sp = (-s) % s_chunk
    if sp:
        k = jnp.pad(k, ((0, 0), (0, sp), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp), (0, 0), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, sp)), constant_values=NEG_INF)
    ss = s + sp
    ns = ss // s_chunk
    qg = q.reshape(b, kh, g, d)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=(b, kh, ns),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda i, j, sj: (i, j, 0, 0)),
            pl.BlockSpec((1, s_chunk, 1, d), lambda i, j, sj: (i, sj, j, 0)),
            pl.BlockSpec((1, s_chunk, 1, d), lambda i, j, sj: (i, sj, j, 0)),
            pl.BlockSpec((1, s_chunk), lambda i, j, sj: (i, sj)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, j, sj: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, g), jnp.float32),
            pltpu.VMEM((1, g), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, bias)
    return out.reshape(b, h, d)
