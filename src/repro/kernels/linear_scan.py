"""Pallas TPU kernel for the diagonal linear recurrence
``h_t = a_t * h_{t-1} + b_t`` — the sequential core shared by the RG-LRU
(recurrentgemma) and the Mamba-2 cross-chunk state pass.

Layout: (T, N) float32 with the channel dimension on lanes. Grid =
(N blocks, T chunks); T chunks iterate fastest (sequential on TPU) with
the running state carried in VMEM scratch, so HBM traffic is exactly one
read of (a, b) and one write of h — the recurrence never leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_T_CHUNK = 256
DEFAULT_N_BLOCK = 512


def _scan_kernel(a_ref, b_ref, h0_ref, h_ref, carry_ref, *, t_chunk: int):
    tj = pl.program_id(1)

    @pl.when(tj == 0)
    def _init():
        carry_ref[...] = h0_ref[...]

    def body(s, h):
        h = a_ref[s, :] * h + b_ref[s, :]
        h_ref[s, :] = h
        return h

    carry_ref[0, :] = jax.lax.fori_loop(0, t_chunk, body, carry_ref[0, :])


def linear_scan_pallas(a, b, h0=None,
                       t_chunk: int = DEFAULT_T_CHUNK,
                       n_block: int = DEFAULT_N_BLOCK,
                       interpret: bool = True):
    """a, b: (T, N) f32; h0: (N,) or None. Returns h (T, N)."""
    t, n = a.shape
    if h0 is None:
        h0 = jnp.zeros((n,), jnp.float32)
    t_chunk = min(t_chunk, t)
    n_block = min(n_block, n)
    tp = (-t) % t_chunk
    npad = (-n) % n_block
    if tp or npad:
        a = jnp.pad(a, ((0, tp), (0, npad)), constant_values=1.0)
        b = jnp.pad(b, ((0, tp), (0, npad)))
        h0 = jnp.pad(h0, (0, npad))
    tt, nn = t + tp, n + npad
    nt, nb = tt // t_chunk, nn // n_block

    h = pl.pallas_call(
        functools.partial(_scan_kernel, t_chunk=t_chunk),
        grid=(nb, nt),
        in_specs=[
            pl.BlockSpec((t_chunk, n_block), lambda i, j: (j, i)),
            pl.BlockSpec((t_chunk, n_block), lambda i, j: (j, i)),
            pl.BlockSpec((1, n_block), lambda i, j: (0, i)),
        ],
        out_specs=pl.BlockSpec((t_chunk, n_block), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((tt, nn), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, n_block), jnp.float32)],
        interpret=interpret,
    )(a, b, h0[None, :])
    return h[:t, :n]
