"""Training driver: the IMPALA loop (actors -> queue -> V-trace learner)
with checkpointing, replay, policy lag, and optional multi-task suites.

Two runtimes:
  --runtime sync    one loop, acting and learning interleaved; policy lag
                    is *simulated* deterministically (LagController), the
                    right mode for controlled lag/correction experiments.
  --runtime async   real concurrency (repro.distributed): N actors feed a
                    backpressured transport, the learner drains it with
                    dynamic batching, and per-trajectory policy lag is
                    *measured* from parameter-store versions. Actors run
                    as threads (--actor-backend thread, zero-copy
                    in-process queue) or as spawned processes
                    (--actor-backend process --transport shm, serialized
                    trajectory buffers over a cross-process wire — acting
                    stops competing with the learner for the GIL).

CPU-scale entry points (real envs, real learning):
  PYTHONPATH=src python -m repro.launch.train --arch impala-shallow \
      --env catch --steps 500 --num-envs 32
  PYTHONPATH=src python -m repro.launch.train --runtime async \
      --actor-threads 4 --env catch --steps 200 --smoke
  PYTHONPATH=src python -m repro.launch.train --runtime async \
      --actor-backend process --transport shm --env catch \
      --steps 100 --smoke

The production mesh path for the assigned architectures is exercised by
``repro.launch.dryrun`` (compile-only on this CPU-only box).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="impala-shallow")
    p.add_argument("--env", default="catch")
    p.add_argument("--steps", type=int, default=500)
    p.add_argument("--num-envs", type=int, default=32)
    p.add_argument("--unroll", type=int, default=20)
    p.add_argument("--lr", type=float, default=6e-4)
    p.add_argument("--entropy-cost", type=float, default=0.003)
    p.add_argument("--rmsprop-eps", type=float, default=0.01)
    p.add_argument("--policy-lag", type=int, default=1,
                   help="simulated lag (sync runtime only; async measures)")
    p.add_argument("--correction", default="vtrace",
                   choices=["vtrace", "onestep_is", "eps", "none"])
    p.add_argument("--replay-fraction", type=float, default=0.0,
                   help="share of each trained batch drawn from the "
                        "trajectory replay buffer (0 disables replay; "
                        "the paper's replay experiments use 0.5). The "
                        "async learner caps fresh collection at "
                        "(1-fraction) of the batch and tops it up with "
                        "replayed rows, so env-frame consumption per "
                        "update drops by the same share")
    p.add_argument("--replay-capacity", type=int, default=10_000,
                   help="replay buffer size in trajectories (FIFO ring)")
    p.add_argument("--replay-reuse", type=int, default=2,
                   help="K: max TOTAL consumptions per trajectory "
                        "(online pass included); 0 = unlimited. The "
                        "IMPACT-style reuse cap")
    p.add_argument("--replay-priority", default="pertd",
                   choices=["pertd", "uniform"],
                   help="replay sampling: 'pertd' draws proportional to "
                        "the last-seen V-trace advantage magnitude "
                        "(Ape-X prioritization), 'uniform' is the "
                        "paper's uniform mix")
    p.add_argument("--replay-target-period", type=int, default=16,
                   help="updates between target-network syncs: replayed "
                        "rows take the target's values as the V-trace "
                        "baseline (IMPACT), so K reuses chase a fixed "
                        "target")
    p.add_argument("--reward-clip", default="abs_one")
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced smoke config of --arch")
    p.add_argument("--runtime", default="sync", choices=["sync", "async"])
    p.add_argument("--actor-threads", type=int, default=2,
                   help="actor worker count (async runtime; threads or "
                        "processes per --actor-backend). With "
                        "--learners N this is the TOTAL slot count, "
                        "sharded contiguously over the learners")
    p.add_argument("--learners", type=int, default=1,
                   help="learner worker count (async runtime). 1 (the "
                        "default) runs the single-learner loop in this "
                        "process. N>1 spawns N learner processes, each "
                        "owning a disjoint shard of the actor slots and "
                        "its own transport; gradients are mean-reduced "
                        "over a CRC-framed TCP channel every round and "
                        "learner 0 (the designated publisher) numbers "
                        "the param versions. With --listen HOST:PORT, "
                        "learner k binds PORT+k and external actors may "
                        "dial any of them (a full learner refuses with "
                        "the shard map; the actor spills)")
    p.add_argument("--learner-mode", default="process",
                   choices=["process", "spmd"],
                   help="how data-parallel learning scales (async "
                        "runtime): 'process' is the hub/spoke learner "
                        "group (--learners N spawns N processes "
                        "exchanging gradients over TCP); 'spmd' keeps "
                        "ONE learner process and runs the train step as "
                        "a shard_map over --spmd-devices local devices "
                        "— batch sharded on the trajectory axis, params "
                        "replicated, gradients mean-reduced by an "
                        "in-XLA psum (zero TCP frames). Same update "
                        "math as a --learners N group at equal global "
                        "batch")
    p.add_argument("--spmd-devices", type=int, default=0,
                   help="device count for --learner-mode spmd (0 = all "
                        "local devices). On CPU, grow the pool with "
                        "XLA_FLAGS=--xla_force_host_platform_device_"
                        "count=N before launch")
    p.add_argument("--coord-addr", default="",
                   help="multi-host SPMD stub: HOST:PORT of the "
                        "jax.distributed coordinator (process 0). "
                        "Calls jax.distributed.initialize before any "
                        "device use so the ('data',) mesh can span "
                        "hosts; single-host runs leave it empty")
    p.add_argument("--num-hosts", type=int, default=1,
                   help="total participating hosts for --coord-addr")
    p.add_argument("--host-id", type=int, default=0,
                   help="this host's process index for --coord-addr")
    p.add_argument("--grad-stale-s", type=float, default=180.0,
                   help="learner-group stale-grad deadline: the hub "
                        "reduces a round without a learner that missed "
                        "this window (the dropped gradient is counted; "
                        "the laggard still applies the broadcast mean, "
                        "so replicas stay identical)")
    p.add_argument("--actor-backend", default="thread",
                   choices=["thread", "process", "remote"],
                   help="where actors live: threads of this interpreter "
                        "(zero-copy), spawned processes (serialized "
                        "trajectories, no GIL contention), or remote "
                        "machines dialing a TCP listen address "
                        "(--transport socket; without --listen the "
                        "learner spawns loopback children itself)")
    p.add_argument("--actor-mode", default="unroll",
                   choices=["unroll", "inference"],
                   help="unroll: every actor runs its own jitted n-step "
                        "unroll with a private params copy. inference: "
                        "actors are host-side env steppers submitting to "
                        "one dynamic-batching InferenceService on the "
                        "learner's device (paper §3.1; conv-LSTM archs)")
    p.add_argument("--infer-flush-ms", type=float, default=20.0,
                   help="inference service flush deadline: a pending "
                        "request is never delayed past this waiting for "
                        "a fuller batch (actor_mode=inference)")
    p.add_argument("--no-donate", action="store_true",
                   help="disable donate_argnums on the async learner's "
                        "train step (donation updates params/opt_state "
                        "in place; published params become a device "
                        "copy)")
    p.add_argument("--transport", default="",
                   choices=["", "inproc", "shm", "socket"],
                   help="trajectory transport; default inproc for thread "
                        "actors, shm (serialized buffers over a "
                        "cross-process wire) for process actors, socket "
                        "(CRC-framed TCP) for remote actors")
    p.add_argument("--listen", default="",
                   help="HOST:PORT the learner binds for remote actors "
                        "(actor_backend=remote). Given: wait for "
                        "--actor-threads external actors to dial in. "
                        "Empty: loopback ephemeral port, learner spawns "
                        "its own loopback actor children")
    p.add_argument("--connect", default="",
                   help="run as REMOTE ACTOR(S) instead of a learner: "
                        "dial HOST:PORT, receive the whole run config "
                        "in the handshake (env/arch/seed/mode), act "
                        "until the learner says stop. --actor-threads "
                        "sets how many actor processes this machine "
                        "contributes")
    p.add_argument("--wire-codec", default="none",
                   choices=["none", "bf16", "int8"],
                   help="quantize serialized wire payloads: published "
                        "params, trajectory observations (shm/socket "
                        "transports), and grouped gradient frames. "
                        "bf16 halves float bytes losslessly-in-spirit "
                        "(params republish bit-exactly as bf16-rounded "
                        "values); int8 stores per-leaf absmax scales "
                        "(~4x smaller, max error absmax/127). Remote "
                        "actors pick the codec up in the connection "
                        "handshake; a peer that doesn't speak it is "
                        "refused loudly")
    p.add_argument("--vtrace-impl", default="auto",
                   choices=["auto", "fused", "pallas", "scan",
                            "reference"],
                   help="V-trace implementation for the async learner's "
                        "loss: auto = fused Pallas loss kernel on TPU, "
                        "scan elsewhere; fused forces the single-kernel "
                        "softmax+V-trace path (interpret mode off-TPU)")
    p.add_argument("--queue-capacity", type=int, default=8)
    p.add_argument("--queue-policy", default="block",
                   choices=["block", "drop_oldest", "drop_newest"])
    p.add_argument("--max-batch-trajs", type=int, default=4,
                   help="learner dynamic batching: max trajectories "
                        "stacked per update, rounded DOWN to a power of "
                        "two (batch sizes are bucketed so XLA compiles "
                        "at most log2 variants; async runtime)")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=200)
    p.add_argument("--resume", action="store_true",
                   help="let a --learners N group resume from the "
                        "latest fleet-v1 checkpoint in --ckpt-dir "
                        "(params + optimizer state + version, "
                        "continuing the monotonic version stream); "
                        "without it a group refuses to run over an "
                        "existing checkpoint. Single-learner runs "
                        "resume from --ckpt-dir automatically.")
    p.add_argument("--supervise", action="store_true",
                   help="self-healing fleet mode (async runtime): "
                        "heartbeat liveness + lease reaping for remote "
                        "actors, supervised respawn of dead actor "
                        "children / threads / spoke learners (restart "
                        "budget + backoff), hub failover (the lowest "
                        "live learner id is promoted; survivors degrade "
                        "to solo past the deadline), and periodic full "
                        "checkpoints (params + opt state) to --ckpt-dir")
    p.add_argument("--heartbeat-timeout-s", type=float, default=10.0,
                   help="remote-actor liveness deadline (--supervise): "
                        "a slot silent this long has its lease reaped; "
                        "clients heartbeat at a third of it")
    p.add_argument("--elastic", action="store_true",
                   help="with --supervise: let late-dialing remote "
                        "actors grow the slot range past "
                        "--actor-threads instead of being refused")
    p.add_argument("--failover-deadline-s", type=float, default=20.0,
                   help="learner-group hub failover budget: a survivor "
                        "that cannot rejoin a new hub within this many "
                        "seconds degrades to solo training (loud "
                        "degraded_solo telemetry flag)")
    p.add_argument("--log-every", type=int, default=25)
    p.add_argument("--seed", type=int, default=0)
    obs = p.add_argument_group("observability (async runtime)")
    obs.add_argument("--metrics-port", type=int, default=None,
                     help="serve /metrics (Prometheus), /healthz and "
                          "/telemetry (JSON) from a background HTTP "
                          "server on the learner (0 = ephemeral port; "
                          "with --learners N the parent aggregates the "
                          "whole group behind this one port)")
    obs.add_argument("--metrics-host", default="127.0.0.1",
                     help="bind address for --metrics-port")
    obs.add_argument("--telemetry-json", default="",
                     help="write the complete final telemetry snapshot "
                          "(merged across learners for --learners N) to "
                          "this path as JSON")
    obs.add_argument("--trace", default="", dest="trace_path",
                     help="record sampled per-trajectory lifecycle spans "
                          "(env unroll -> encode -> transport -> queue "
                          "wait -> collect -> step -> publish) and write "
                          "Chrome trace-event JSON here (load in "
                          "Perfetto). Single-learner async runs, "
                          "actor_mode=unroll")
    obs.add_argument("--trace-every", type=int, default=64,
                     help="sample every Nth trajectory per actor for "
                          "--trace")
    obs.add_argument("--profile-steps", default="",
                     help="A:B — wrap learner updates [A, B) in "
                          "jax.profiler.start_trace/stop_trace")
    obs.add_argument("--profile-dir", default="/tmp/repro-profile",
                     help="output directory for --profile-steps traces")
    obs.add_argument("--telemetry-sink", default="",
                     help="append periodic JSONL telemetry snapshots to "
                          "this path while training")
    obs.add_argument("--sink-interval-s", type=float, default=5.0,
                     help="seconds between --telemetry-sink lines")
    args = p.parse_args()

    if args.connect:
        # remote actor mode: this process contributes actors to a
        # learner elsewhere — every run parameter arrives in the
        # connection handshake, so none of the learner flags apply here
        return _run_remote_actors(args)

    if args.coord_addr:
        # multi-host SPMD stub: initialize the jax.distributed runtime
        # BEFORE anything touches the backend, so jax.devices() spans
        # every host and the ('data',) mesh (and its psum) is global.
        # Single-host SPMD never comes through here.
        if args.num_hosts < 1 or not (0 <= args.host_id < args.num_hosts):
            raise SystemExit(f"--coord-addr needs --num-hosts >= 1 and "
                             f"0 <= --host-id < num_hosts, got "
                             f"{args.num_hosts}/{args.host_id}")
        jax.distributed.initialize(coordinator_address=args.coord_addr,
                                   num_processes=args.num_hosts,
                                   process_id=args.host_id)
        print(f"jax.distributed up: host {args.host_id}/{args.num_hosts} "
              f"coordinator={args.coord_addr} "
              f"devices={jax.device_count()} "
              f"(local {jax.local_device_count()})")

    if args.learner_mode == "spmd":
        if args.runtime != "async":
            raise SystemExit("--learner-mode spmd requires "
                             "--runtime async")
        if args.learners > 1:
            raise SystemExit("--learner-mode spmd keeps ONE learner "
                             "process; drop --learners (device "
                             "parallelism comes from --spmd-devices)")

    from repro.configs.base import ImpalaConfig
    from repro.configs.registry import get_config, get_smoke_config
    from repro.data.envs import make_env

    env = make_env(args.env)
    arch = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if arch.family == "impala_cnn":
        arch = arch.replace(image_hw=env.image_hw)
    elif arch.vocab_size < env.vocab_size:
        arch = arch.replace(vocab_size=env.vocab_size)
    icfg = ImpalaConfig(
        num_actions=env.num_actions, unroll_length=args.unroll,
        learning_rate=args.lr, entropy_cost=args.entropy_cost,
        rmsprop_eps=args.rmsprop_eps, policy_lag=args.policy_lag,
        correction=args.correction, replay_fraction=args.replay_fraction,
        replay_capacity=args.replay_capacity,
        replay_reuse=args.replay_reuse,
        replay_priority=args.replay_priority,
        replay_target_period=args.replay_target_period,
        reward_clip=args.reward_clip, seed=args.seed)

    if args.runtime == "async":
        return _run_async(args, env, arch, icfg)
    return _run_sync(args, env, arch, icfg)


def _build_obs(args):
    """ObsConfig from the CLI flags, or None when no obs flag is set
    (the runtime then skips all instrumentation glue)."""
    wants = (args.metrics_port is not None or args.trace_path
             or args.profile_steps or args.telemetry_sink)
    if not wants:
        return None
    from repro.obs import ObsConfig
    return ObsConfig(
        metrics_port=args.metrics_port,
        metrics_host=args.metrics_host,
        trace_path=args.trace_path or None,
        trace_every=max(1, args.trace_every),
        profile_steps=args.profile_steps or None,
        profile_dir=args.profile_dir,
        sink_path=args.telemetry_sink or None,
        sink_interval_s=args.sink_interval_s)


def _dump_telemetry(path: str, tel) -> None:
    with open(path, "w") as f:
        json.dump(tel, f, default=float, indent=2)
        f.write("\n")
    print(f"telemetry snapshot written to {path}")


def _parse_hostport(spec: str, default_host: str = "127.0.0.1"):
    host, sep, port = spec.rpartition(":")
    if not sep or not port.isdigit():
        raise SystemExit(f"expected HOST:PORT, got {spec!r}")
    return (host or default_host, int(port))


def _run_remote_actors(args) -> int:
    import multiprocessing as mp

    addr = _parse_hostport(args.connect)
    n = max(1, args.actor_threads)
    print(f"remote actor mode: {n} actor process(es) -> "
          f"{addr[0]}:{addr[1]}")
    if n == 1:
        import os
        from repro.distributed.netserve import remote_actor_main
        err = remote_actor_main(addr)
        if err:
            print(err)
            return 1
        print("learner said stop; exiting cleanly")
        # hard exit: XLA runtime threads can abort C++ teardown on a
        # normal interpreter exit, flipping a clean run's exit code
        os._exit(0)
    ctx = mp.get_context("spawn")
    from repro.distributed.netserve import remote_actor_child
    from repro.distributed.supervise import KillSafeEvent
    stop = KillSafeEvent(ctx)
    procs = [ctx.Process(target=remote_actor_child, args=(addr, stop),
                         name=f"remote-actor-{i}") for i in range(n)]
    for proc in procs:
        proc.start()
    try:
        for proc in procs:
            proc.join()
    except KeyboardInterrupt:
        stop.set()
        for proc in procs:
            proc.join(timeout=10)
            if proc.is_alive():
                proc.terminate()
        return 0
    # a failed actor (dial timeout, refusal, crash) exits nonzero;
    # surface it like the single-actor path does
    return 1 if any(p.exitcode not in (0, None) for p in procs) else 0


def _run_sync(args, env, arch, icfg) -> int:
    from repro.core import actor as actor_lib
    from repro.core import learner as learner_lib
    from repro.core.metrics import EpisodeTracker
    from repro.core.queue import LagController
    from repro.core.replay import ReplayBuffer, mix_batches
    from repro.checkpoint import checkpoint as ckpt
    from repro.models import backbone as bb
    from repro.models import common

    specs = bb.backbone_specs(arch, env.num_actions)
    params = common.init_params(specs, jax.random.key(args.seed))
    print(f"arch={arch.name} params={common.param_count(specs):,} "
          f"env={env.name} actions={env.num_actions} runtime=sync")

    init_fn, unroll = actor_lib.build_actor(env, arch, icfg, args.num_envs)
    train_step, opt = learner_lib.build_train_step(arch, icfg,
                                                   env.num_actions)
    train_step = jax.jit(train_step)
    opt_state = opt.init(params)
    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        params, start_step = ckpt.restore(args.ckpt_dir, params)
        print(f"restored checkpoint at step {start_step}")

    carry = init_fn(jax.random.key(args.seed + 1))
    lag = LagController(icfg.policy_lag, params)
    buf = ReplayBuffer(icfg.replay_capacity, seed=args.seed,
                       reuse_limit=icfg.replay_reuse,
                       priority=icfg.replay_priority)
    tracker = EpisodeTracker(args.num_envs)
    frames = 0
    # steady-state fps window opens after the first jitted update lands —
    # otherwise early prints are dominated by XLA compile time (matching
    # the async runtime's convention)
    t0 = None
    frames0 = 0
    for step in range(start_step, args.steps):
        # acting and learning interleave directly — no queue theatre: the
        # trajectory IS the batch (the real queue lives in the async path)
        carry, batch = unroll(lag.actor_params(), carry)
        tracker.update(np.asarray(batch["rewards"]),
                       np.asarray(batch["done"]))
        if icfg.replay_fraction > 0:
            buf.add_batch(batch)
            rep = buf.sample(args.num_envs)
            batch = mix_batches(batch, rep, icfg.replay_fraction,
                                buffer=buf)
        params, opt_state, metrics = train_step(params, opt_state,
                                                jnp.int32(step), batch)
        lag.on_update(params)
        frames += args.num_envs * args.unroll
        if t0 is None:
            jax.block_until_ready(params)
            t0 = time.time()
            frames0 = frames
        if (step + 1) % args.log_every == 0:
            dt = time.time() - t0
            fps = (frames - frames0) / dt if dt > 0 else 0.0
            print(f"step {step+1:6d} return(100)={tracker.mean_return():7.3f} "
                  f"loss={float(metrics['loss/total']):10.2f} "
                  f"entropy={-float(metrics['loss/entropy']):8.1f} "
                  f"fps={fps:7.0f} episodes={len(tracker.completed)}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, params)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, params)
    print(f"final return(100) = {tracker.mean_return():.3f}")
    return 0


def _run_async(args, env, arch, icfg) -> int:
    from repro.checkpoint import checkpoint as ckpt
    from repro.distributed import run_async_training
    from repro.models import backbone as bb
    from repro.models import common

    transport = args.transport or {
        "process": "shm", "remote": "socket"}.get(args.actor_backend,
                                                  "inproc")
    if args.actor_backend == "process" and transport != "shm":
        raise SystemExit("--actor-backend process requires --transport shm")
    if args.actor_backend == "remote" and transport != "socket":
        raise SystemExit("--actor-backend remote requires "
                         "--transport socket")
    if args.learners > 1:
        return _run_group(args, env, arch, icfg, transport)
    listen_addr = (_parse_hostport(args.listen, default_host="0.0.0.0")
                   if args.listen else None)
    # an explicit --listen means real remote machines dial in; without
    # it the learner spawns loopback actor children itself
    spawn_remote = not args.listen
    spmd_devices = 0
    if args.learner_mode == "spmd":
        spmd_devices = args.spmd_devices or jax.device_count()
    specs = bb.backbone_specs(arch, env.num_actions)
    print(f"arch={arch.name} params={common.param_count(specs):,} "
          f"env={env.name} actions={env.num_actions} runtime=async "
          f"actors={args.actor_threads}({args.actor_backend}/"
          f"{args.actor_mode}) transport={transport} "
          f"queue={args.queue_capacity}/{args.queue_policy} "
          f"max_batch_trajs={args.max_batch_trajs} "
          f"donate={not args.no_donate}"
          + (f" learner_mode=spmd spmd_devices={spmd_devices}"
             if spmd_devices else ""))
    initial_params, initial_opt, start_step = None, None, 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        tree, ck_step, extra = ckpt.load_with_extra(args.ckpt_dir)
        if (extra or {}).get("format") == "fleet-v1":
            # full resume: params + optimizer state + version — the
            # run continues the exact monotonic version stream
            initial_params, initial_opt = tree["params"], tree["opt"]
            start_step = int(extra.get("version", ck_step))
            print(f"restored fleet checkpoint at version {start_step} "
                  f"(params + optimizer state)")
        else:
            like = common.init_params(specs, jax.random.key(args.seed))
            initial_params, start_step = ckpt.restore(args.ckpt_dir,
                                                      like)
            print(f"restored checkpoint at step {start_step}")

    last_params = [None]

    def on_update(step, params, metrics, snapshot_fn):
        last_params[0] = params
        if step % args.log_every == 0:
            tel = snapshot_fn()
            lag = tel["lag"]
            q = tel["queue"]
            extra = ""
            if "inference" in tel:
                inf = tel["inference"]
                extra = (f" infer(batch/wait_p95)="
                         f"{inf['mean_batch']:.1f}/"
                         f"{inf['queue_wait_ms_p95']:.1f}ms")
            print(f"update {step:6d} "
                  f"loss={float(metrics['loss/total']):10.2f} "
                  f"lag(mean/max)={lag['mean']:.2f}/{lag['max']} "
                  f"queue(occ/drop/stall)={q['mean_occupancy']:.1f}/"
                  f"{q['dropped']}/{q['put_stalls']} "
                  f"learner_fps={tel['frames_per_sec']:7.0f} "
                  f"actor_fps={tel['actors']['actor_fps']:7.0f}" + extra)
        if args.ckpt_dir and step % args.ckpt_every == 0 and \
                not args.supervise:
            # legacy params-only saves; --supervise switches to the
            # runtime's combined fleet-v1 checkpoints instead
            ckpt.save(args.ckpt_dir, step, params)

    env_arg = (args.env if args.actor_backend in ("process", "remote")
               else env)
    tracker, metrics, tel = run_async_training(
        env_arg, icfg, args.num_envs, args.steps,
        num_actors=args.actor_threads,
        actor_backend=args.actor_backend,
        actor_mode=args.actor_mode,
        transport=transport,
        listen_addr=listen_addr,
        spawn_remote=spawn_remote,
        queue_capacity=args.queue_capacity,
        queue_policy=args.queue_policy,
        max_batch_trajs=args.max_batch_trajs,
        donate=not args.no_donate,
        infer_flush_timeout_s=args.infer_flush_ms / 1e3,
        wire_codec=args.wire_codec, vtrace_impl=args.vtrace_impl,
        spmd_devices=spmd_devices,
        seed=args.seed, arch=arch, initial_params=initial_params,
        initial_opt_state=initial_opt,
        start_step=start_step, on_update=on_update,
        supervise=args.supervise,
        heartbeat_timeout_s=args.heartbeat_timeout_s,
        elastic=args.elastic,
        ckpt_dir=(args.ckpt_dir if args.supervise else None),
        ckpt_every=args.ckpt_every,
        obs=_build_obs(args))
    if args.ckpt_dir and last_params[0] is not None and \
            not args.supervise:
        ckpt.save(args.ckpt_dir, args.steps, last_params[0])
    print(f"final return(100) = {tracker.mean_return():.3f}")
    keys = ["learner_updates", "frames_consumed", "updates_per_sec",
            "frames_per_sec", "batch_size_hist", "lag", "queue",
            "actors", "param_version"]
    if "inference" in tel:
        keys.append("inference")
    if "replay" in tel:
        keys.append("replay")
    if "group" in tel:
        # spmd runs surface the group section (collective backend)
        keys += ["group", "exchange"]
    print("telemetry:", json.dumps({k: tel[k] for k in keys},
                                   default=float))
    if args.telemetry_json:
        _dump_telemetry(args.telemetry_json, tel)
    return 0


def _run_group(args, env, arch, icfg, transport) -> int:
    """N>1 learner processes: sharded actors, gradient exchange over
    the framed channel, one designated publisher. With --supervise the
    group writes fleet-v1 checkpoints (params + optimizer state +
    version) every ``--ckpt-every`` updates and resumes from the latest
    one, continuing the same monotonic version stream. ``transport``
    arrives resolved/validated from _run_async."""
    from repro.checkpoint import checkpoint as ckpt
    from repro.distributed import run_group_training
    from repro.models import backbone as bb
    from repro.models import common

    resume_from = None
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        step0 = ckpt.latest_step(args.ckpt_dir)
        man = ckpt.read_manifest(args.ckpt_dir)
        fleet = man.get("extra", {}).get("format") == "fleet-v1"
        if not args.resume:
            # refusing beats silently restarting from scratch AND
            # overwriting the existing checkpoint at the end
            hint = ("pass --resume to continue it"
                    if fleet else "move it aside or pick a fresh "
                                  "--ckpt-dir")
            raise SystemExit(
                f"{args.ckpt_dir!r} already holds a checkpoint "
                f"(step {step0}); {hint}.")
        if fleet:
            resume_from = args.ckpt_dir
            print(f"resuming learner group from fleet checkpoint "
                  f"(step {step0})")
        else:
            # a params-only checkpoint has no optimizer state to hand
            # the workers — refusing beats silently restarting from
            # scratch AND overwriting the existing checkpoint
            raise SystemExit(
                f"{args.ckpt_dir!r} holds a params-only checkpoint "
                f"(step {step0}); a learner group resumes only from "
                f"fleet-v1 checkpoints (params + optimizer state — "
                f"written by --supervise runs). Move it aside or pick "
                f"a fresh --ckpt-dir.")
    listen_addr = (_parse_hostport(args.listen, default_host="0.0.0.0")
                   if args.listen else None)
    spawn_remote = not args.listen
    specs = bb.backbone_specs(arch, env.num_actions)
    print(f"arch={arch.name} params={common.param_count(specs):,} "
          f"env={env.name} actions={env.num_actions} runtime=async "
          f"learners={args.learners} "
          f"actors={args.actor_threads}({args.actor_backend}/"
          f"{args.actor_mode}) transport={transport} "
          f"queue={args.queue_capacity}/{args.queue_policy} "
          f"max_batch_trajs={args.max_batch_trajs} "
          f"donate={not args.no_donate}")
    def on_progress(learner_id, snap):
        lag = snap["lag"]
        q = snap["queue"]
        ex = snap.get("exchange", {})
        print(f"learner {learner_id} update {snap['learner_updates']:6d} "
              f"lag(mean/max)={lag['mean']:.2f}/{lag['max']} "
              f"queue(occ/stall)={q.get('mean_occupancy', 0.0):.1f}/"
              f"{q.get('put_stalls', 0)} "
              f"fps={snap['frames_per_sec']:7.0f} "
              f"reduce_ms={ex.get('reduce_wait_ms_mean', 0.0):.1f} "
              f"stale={ex.get('stale_dropped', 0)}", flush=True)

    tracker, metrics, tel, params = run_group_training(
        args.env, icfg, args.num_envs, args.steps,
        num_learners=args.learners,
        num_actors=args.actor_threads,
        actor_backend=args.actor_backend,
        actor_mode=args.actor_mode,
        transport=transport,
        listen_addr=listen_addr,
        spawn_remote=spawn_remote,
        queue_capacity=args.queue_capacity,
        queue_policy=args.queue_policy,
        max_batch_trajs=args.max_batch_trajs,
        donate=not args.no_donate,
        stale_after_s=args.grad_stale_s,
        infer_flush_timeout_s=args.infer_flush_ms / 1e3,
        wire_codec=args.wire_codec, vtrace_impl=args.vtrace_impl,
        seed=args.seed, arch=arch,
        telemetry_every=args.log_every, on_progress=on_progress,
        ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
        # supervised groups save fleet-v1 (params + opt state) through
        # ckpt_dir; legacy params-only saves would mix formats
        on_checkpoint=(lambda step, p: ckpt.save(args.ckpt_dir, step, p))
        if args.ckpt_dir and not args.supervise else None,
        supervise=args.supervise,
        failover_deadline_s=args.failover_deadline_s,
        resume_from=resume_from,
        ckpt_dir=args.ckpt_dir if args.supervise else None,
        return_final_params=True, obs=_build_obs(args))
    if args.ckpt_dir and not args.supervise:
        ckpt.save(args.ckpt_dir, args.steps, params)
    print(f"final return(100) = {tracker.mean_return():.3f}")
    keys = ["group", "learner_updates", "frames_consumed",
            "updates_per_sec", "frames_per_sec", "lag", "actors",
            "param_version"]
    if "replay" in tel:
        keys.append("replay")
    print("telemetry:", json.dumps({k: tel[k] for k in keys},
                                   default=float))
    per = tel["actors"]["per_learner_trajectories"]
    print("per-learner trajectories:", json.dumps(per))
    if args.telemetry_json:
        _dump_telemetry(args.telemetry_json, tel)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
