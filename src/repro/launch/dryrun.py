import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production mesh, record memory/cost/collective analysis.

The two lines above MUST stay first: jax locks the device count at first
init, and the dry-run needs 512 placeholder host devices. Smoke tests and
benchmarks import other modules and see 1 device.

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k \
      [--multi-pod] [--out results/dryrun] [--rules baseline]
  python -m repro.launch.dryrun --list
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp


def run_pair(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: str, rules_name: str = "baseline",
             vtrace_impl: str = "scan",
             moe_impl: str = "shard_map_a2a",
             mixed_precision: bool = False,
             remat_off: bool = False) -> dict:
    from repro.configs.base import INPUT_SHAPES
    from repro.configs.registry import get_config
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import HW, make_production_mesh
    from repro.roofline import analysis
    from repro.sharding.rules import Rules
    from repro.sharding import profiles

    shape = INPUT_SHAPES[shape_name]
    arch = get_config(arch_name)
    used_name = arch_name
    if shape_name == "long_500k" and arch_name == "mistral-nemo-12b":
        from repro.configs.mistral_nemo_12b import swa_variant
        arch = swa_variant()
        used_name = arch.name
    # unroll layers so cost_analysis FLOPs/bytes are honest (a lax.scan
    # while-body is counted once regardless of trip count)
    arch = arch.replace(scan_layers=False)
    if arch.moe is not None and moe_impl:
        import dataclasses as _dc
        arch = arch.replace(moe=_dc.replace(arch.moe, dispatch_impl=moe_impl))
    if remat_off:
        arch = arch.replace(remat=False)

    tag = rules_name
    if arch.moe is not None and moe_impl == "dense_einsum":
        tag = rules_name + "+densemoe"
    if mixed_precision:
        tag = tag + "+mp"
    if remat_off:
        tag = tag + "+noremat"
    rec = {
        "arch": arch_name, "arch_used": used_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "rules": tag, "moe_impl": moe_impl if arch.moe else None,
        "status": "pending",
    }
    ok, why = steps_lib.pair_supported(arch, shape)
    if not ok:
        rec["status"] = "skip"
        rec["reason"] = why
        return _finish(rec, out_dir)

    t0 = time.time()
    try:
        if rules_name == "tp2d":
            from repro.launch.mesh import make_mesh_2d_tp
            mesh = make_mesh_2d_tp(multi_pod=multi_pod)
        else:
            mesh = make_production_mesh(multi_pod=multi_pod)
        rules = Rules(mesh, profiles.get_profile(rules_name, arch, shape))
        lowered, meta = steps_lib.lower_pair(
            arch, shape, mesh, rules, vtrace_impl=vtrace_impl,
            mixed_precision=mixed_precision)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        cost = analysis.executable_cost(compiled)
        hlo = compiled.as_text()
        from repro.roofline import memory_model, flops_model
        mem_model = memory_model.estimate(arch, shape, rules)
        a_flops, a_bytes = flops_model.step_cost(arch, shape, n_devices=(
            512 if multi_pod else 256))
        n_dev = 512 if multi_pod else 256
        mf = analysis.model_flops(arch, meta["params"], shape,
                                  per_device=True, n_devices=n_dev)
        roof = analysis.analyse(cost, hlo, HW, model_flops=mf)
        rec.update({
            "status": "ok",
            "n_params": meta["params"],
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_estimate_bytes": (mem.argument_size_in_bytes +
                                        mem.output_size_in_bytes +
                                        mem.temp_size_in_bytes -
                                        mem.alias_size_in_bytes),
            },
            # analytic per-device TPU HBM model (CPU temp accounting is a
            # parallel-scheduler upper bound; see roofline/memory_model.py)
            "memory_model": mem_model,
            "cost": {
                "flops_per_device": roof.flops_per_device,
                "bytes_per_device": roof.bytes_per_device,
            },
            "collectives": roof.collectives,
            # hlo_* terms come from cost_analysis (blind to inner chunk
            # scans); analytic_* from roofline/flops_model.py. The table
            # uses analytic flops/bytes + HLO collectives.
            "analytic": {
                "flops_per_device": a_flops,
                "bytes_per_device": a_bytes,
                "compute_s": a_flops / HW["peak_flops_bf16"],
                "memory_s": a_bytes / HW["hbm_bw"],
            },
            "roofline": {
                "hlo_compute_s": roof.compute_s,
                "hlo_memory_s": roof.memory_s,
                "compute_s": a_flops / HW["peak_flops_bf16"],
                "memory_s": a_bytes / HW["hbm_bw"],
                "collective_s": roof.collective_s,
                "bottleneck": max(
                    {"compute": a_flops / HW["peak_flops_bf16"],
                     "memory": a_bytes / HW["hbm_bw"],
                     "collective": roof.collective_s}.items(),
                    key=lambda kv: kv[1])[0],
                "model_flops_per_device": mf,
                "useful_flops_ratio": mf / max(a_flops, 1.0),
            },
            "hlo_bytes": len(hlo),
        })
    except Exception as e:  # noqa: BLE001 — record failures, don't crash sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return _finish(rec, out_dir)


def _finish(rec: dict, out_dir: str) -> dict:
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        pod = "pod2" if rec["mesh"].startswith("2x") else "pod1"
        name = f"{rec['arch']}_{rec['shape']}_{pod}_{rec['rules']}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)
    line = (f"[{rec['status']:5s}] {rec['arch']:24s} {rec['shape']:12s} "
            f"{rec['mesh']:8s} rules={rec['rules']}")
    if rec["status"] == "ok":
        r = rec["roofline"]
        line += (f" compile={rec['compile_s']:.0f}s "
                 f"compute={r['compute_s']*1e3:.2f}ms "
                 f"memory={r['memory_s']*1e3:.2f}ms "
                 f"coll={r['collective_s']*1e3:.2f}ms "
                 f"-> {r['bottleneck']}")
    elif rec["status"] == "error":
        line += " " + rec["error"][:160]
    print(line, flush=True)
    return rec


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=False)
    p.add_argument("--shape", required=False)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--rules", default="baseline")
    p.add_argument("--vtrace-impl", default="scan")
    p.add_argument("--moe-impl", default="shard_map_a2a",
                   choices=["shard_map_a2a", "dense_einsum"])
    p.add_argument("--mixed-precision", action="store_true")
    p.add_argument("--remat-off", action="store_true")
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--list", action="store_true")
    args = p.parse_args()
    if args.list:
        from repro.configs.base import INPUT_SHAPES
        from repro.configs.registry import ASSIGNED
        for a in ASSIGNED:
            for s in INPUT_SHAPES:
                print(a.replace("_", "-"), s)
        return 0
    rec = run_pair(args.arch, args.shape, args.multi_pod, args.out,
                   args.rules, args.vtrace_impl, args.moe_impl,
                   args.mixed_precision, args.remat_off)
    return 0 if rec["status"] in ("ok", "skip") else 1


if __name__ == "__main__":
    sys.exit(main())
