"""Serving driver: batched actor-inference service (the GA3C/dynamic-
batching role from paper §3.1/Fig. 2, as a standalone process).

Requests (observation streams) arrive on a host-side queue; the server
batches up to ``--batch`` concurrent streams, prefills each stream's
context once, then steps all streams in lockstep through ``serve_step``
(one action per stream per tick) — the decode path the decode_32k /
long_500k shapes lower on the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \
      --smoke --requests 64 --ctx 128
"""
from __future__ import annotations

import argparse
import collections
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="mistral-nemo-12b")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--requests", type=int, default=64)
    p.add_argument("--ctx", type=int, default=128)
    p.add_argument("--decode-steps", type=int, default=32)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    from repro.configs.registry import get_config, get_smoke_config
    from repro.models import backbone as bb
    from repro.models import common

    A = 18
    arch = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    arch = arch.replace(vocab_size=max(arch.vocab_size, 4096))
    specs = bb.backbone_specs(arch, A)
    params = common.init_params(specs, jax.random.key(args.seed))
    print(f"serving {arch.name} ({common.param_count(specs):,} params), "
          f"batch={args.batch}")

    prefill = jax.jit(lambda p, t: bb.apply_prefill(p, {"tokens": t},
                                                    arch, A))
    decode = jax.jit(lambda p, tok, c, i: bb.apply_decode(p, tok, c, i,
                                                          arch, A))

    # synthetic request queue: each request = a ctx-length observation stream
    rng = np.random.default_rng(args.seed)
    pending = collections.deque(
        rng.integers(0, arch.vocab_size, size=(args.requests, args.ctx))
        .astype(np.int32))

    served = 0
    t0 = time.time()
    lat = []
    while pending:
        # dynamic batching: take up to --batch requests
        batch = [pending.popleft() for _ in range(min(args.batch,
                                                      len(pending)))]
        n = len(batch)
        if n < args.batch:  # pad the batch (server keeps shapes static)
            batch += [batch[-1]] * (args.batch - n)
        toks = jnp.asarray(np.stack(batch))
        t1 = time.time()
        out = prefill(params, toks)
        cache = out.cache
        tok = toks[:, -1:]
        key = jax.random.key(served)
        for i in range(args.decode_steps):
            out = decode(params, tok, cache, jnp.int32(args.ctx + i))
            cache = out.cache
            key, k = jax.random.split(key)
            action = jax.random.categorical(k, out.policy_logits[:, 0])
            tok = (action[:, None] % arch.vocab_size).astype(jnp.int32)
        jax.block_until_ready(tok)
        lat.append((time.time() - t1) / args.decode_steps * 1e3)
        served += n
    dt = time.time() - t0
    print(f"served {served} streams x {args.decode_steps} actions in "
          f"{dt:.2f}s  ({served*args.decode_steps/dt:.0f} actions/s, "
          f"p50 step latency {np.percentile(lat, 50):.1f}ms)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
