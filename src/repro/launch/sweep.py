"""Dry-run sweep orchestrator: every (arch x shape) on 1-pod and 2-pod
meshes, each in its own subprocess (fresh 512-device jax), bounded
parallelism. Results land in results/dryrun/*.json; aggregate with
``python -m repro.roofline.table``.

Usage: python -m repro.launch.sweep [--jobs 3] [--multi-pod-only|--single-pod-only]
       [--arch A ...] [--shape S ...] [--skip-done]
"""
from __future__ import annotations

import argparse
import itertools
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

ARCHS = [
    "recurrentgemma-2b", "granite-moe-1b-a400m", "whisper-small",
    "mamba2-1.3b", "stablelm-1.6b", "gemma-7b", "qwen1.5-4b",
    "llama-3.2-vision-11b", "mistral-nemo-12b", "olmoe-1b-7b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_one(arch: str, shape: str, multi_pod: bool, out: str,
            rules: str = "baseline", timeout: int = 3600) -> int:
    pod = "pod2" if multi_pod else "pod1"
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out,
           "--rules", rules]
    if multi_pod:
        cmd.append("--multi-pod")
    t0 = time.time()
    try:
        r = subprocess.run(cmd, timeout=timeout, capture_output=True,
                           text=True)
        sys.stdout.write(r.stdout)
        if r.returncode != 0:
            sys.stdout.write(f"!! {arch} {shape} {pod} rc={r.returncode}\n"
                             + r.stderr[-1500:] + "\n")
        sys.stdout.flush()
        return r.returncode
    except subprocess.TimeoutExpired:
        print(f"!! {arch} {shape} {pod} TIMEOUT after {time.time()-t0:.0f}s",
              flush=True)
        return 124


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--jobs", type=int, default=3)
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--rules", default="baseline")
    p.add_argument("--arch", nargs="*", default=ARCHS)
    p.add_argument("--shape", nargs="*", default=SHAPES)
    p.add_argument("--single-pod-only", action="store_true")
    p.add_argument("--multi-pod-only", action="store_true")
    p.add_argument("--skip-done", action="store_true")
    p.add_argument("--timeout", type=int, default=3600)
    args = p.parse_args()

    pods = [False, True]
    if args.single_pod_only:
        pods = [False]
    if args.multi_pod_only:
        pods = [True]

    jobs = []
    for arch, shape, mp in itertools.product(args.arch, args.shape, pods):
        if args.skip_done:
            pod = "pod2" if mp else "pod1"
            f = os.path.join(args.out,
                             f"{arch}_{shape}_{pod}_{args.rules}.json")
            if os.path.exists(f):
                import json
                try:
                    if json.load(open(f)).get("status") in ("ok", "skip"):
                        continue
                except Exception:
                    pass
        jobs.append((arch, shape, mp))

    print(f"sweep: {len(jobs)} jobs, {args.jobs} workers", flush=True)
    rc = 0
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = [ex.submit(run_one, a, s, mp, args.out, args.rules,
                          args.timeout) for a, s, mp in jobs]
        for f in futs:
            rc |= f.result()
    return rc


if __name__ == "__main__":
    sys.exit(main())
