"""Step builders + abstract input specs for every (arch x input-shape).

These produce the jitted, sharded step functions used both by real
training/serving drivers and by the 512-device dry-run (which lowers and
compiles them from ShapeDtypeStructs — no allocation).

Step kinds (DESIGN.md §4):
  train_4k     -> train_step    (V-trace actor-critic update)
  prefill_32k  -> prefill_step  (actor context ingestion, builds cache)
  decode_32k   -> serve_step    (ONE action with a seq_len cache)
  long_500k    -> serve_step    (sub-quadratic archs only)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ImpalaConfig, InputShape
from repro.core import learner as learner_lib
from repro.models import backbone as bb
from repro.models import common
from repro.optim import optimizer as opt_lib
from repro.sharding.rules import Rules, use_rules

PyTree = Any

NUM_ACTIONS = 18  # full Atari action set (paper §5.3.2)


# ---------------------------------------------------------------------------
# Applicability


def decode_cache_len(arch: ArchConfig, seq_len: int) -> int:
    """Context a decode step actually has to hold."""
    if arch.sliding_window:
        return min(arch.sliding_window, seq_len)
    return seq_len


def pair_supported(arch: ArchConfig, shape: InputShape) -> Tuple[bool, str]:
    """Is (arch, shape) runnable? long_500k needs sub-quadratic context."""
    if shape.name != "long_500k":
        return True, ""
    if arch.family in ("ssm", "hybrid"):
        return True, ""
    if arch.sliding_window:
        return True, ""
    return False, ("full quadratic attention cannot hold a 524288-token KV "
                   "cache; runnable only for SSM/hybrid/sliding-window "
                   "variants (DESIGN.md §Arch-applicability)")


# ---------------------------------------------------------------------------
# Abstract inputs


def _stub_inputs(arch: ArchConfig, batch: int) -> Dict[str, jax.ShapeDtypeStruct]:
    dtype = jnp.dtype(arch.dtype)
    if arch.family == "audio":
        return {"enc_embed": jax.ShapeDtypeStruct(
            (batch, arch.encoder_seq_len, arch.d_model), dtype)}
    if arch.family == "vlm":
        return {"image_embed": jax.ShapeDtypeStruct(
            (batch, arch.encoder_seq_len, arch.d_model), dtype)}
    return {}


def input_specs(arch: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this step."""
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind == "train":
        t = s - 1  # s observations, s-1 transitions
        specs = {
            "obs_token": jax.ShapeDtypeStruct((b, s), i32),
            "actions": jax.ShapeDtypeStruct((b, t), i32),
            "rewards": jax.ShapeDtypeStruct((b, t), f32),
            "discounts": jax.ShapeDtypeStruct((b, t), f32),
            "behaviour_logprob": jax.ShapeDtypeStruct((b, t), f32),
        }
        specs.update(_stub_inputs(arch, b))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        specs.update(_stub_inputs(arch, b))
        return specs
    if shape.kind == "decode":
        specs = {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "cache_index": jax.ShapeDtypeStruct((), i32),
            "rng": jax.ShapeDtypeStruct((2,), jnp.uint32),
            "cache": bb.cache_abstract(b, decode_cache_len(arch, s), arch),
        }
        return specs
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Sharding resolution


def batch_logical_axes(arch: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    stub = {"enc_embed": ("batch", None, None),
            "image_embed": ("batch", None, None)}
    if shape.kind == "train":
        ax = {
            "obs_token": ("batch", None),
            "actions": ("batch", None),
            "rewards": ("batch", None),
            "discounts": ("batch", None),
            "behaviour_logprob": ("batch", None),
        }
    elif shape.kind == "prefill":
        ax = {"tokens": ("batch", None)}
    else:
        ax = {
            "token": ("batch", None),
            "cache_index": (),
            "rng": (None,),
            "cache": bb.cache_logical_axes(arch),
        }
    for k in ("enc_embed", "image_embed"):
        if k in input_specs(arch, shape):
            ax[k] = stub[k]
    return ax


def tree_shardings(abstract: PyTree, axes: PyTree, rules: Rules) -> PyTree:
    def leaf(sd, ax):
        return rules.sharding(ax, sd.shape)
    return jax.tree.map(leaf, abstract, axes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ---------------------------------------------------------------------------
# Step functions


def make_impala_config(arch: ArchConfig, vtrace_impl: str = "scan"
                       ) -> ImpalaConfig:
    return ImpalaConfig(num_actions=NUM_ACTIONS, learning_rate=6e-4)


def build_steps(arch: ArchConfig, rules: Rules, vtrace_impl: str = "scan",
                mixed_precision: bool = False):
    """Returns dict of pure step fns closed over configs + rules."""
    icfg = make_impala_config(arch)
    train_step_raw, optimizer = learner_lib.build_train_step(
        arch, icfg, NUM_ACTIONS, vtrace_impl=vtrace_impl,
        mixed_precision=mixed_precision)

    def train_step(params, opt_state, step, batch):
        with use_rules(rules):
            return train_step_raw(params, opt_state, step, batch)

    def prefill_step(params, batch):
        with use_rules(rules):
            out = bb.apply_prefill(params, batch, arch, NUM_ACTIONS)
        return {"policy_logits": out.policy_logits, "values": out.values,
                "cache": out.cache}

    def serve_step(params, token, cache, cache_index, rng):
        with use_rules(rules):
            out = bb.apply_decode(params, token, cache, cache_index, arch,
                                  NUM_ACTIONS)
        logits = out.policy_logits[:, 0]
        action = jax.random.categorical(jax.random.wrap_key_data(rng),
                                        logits, axis=-1)
        logp = jax.nn.log_softmax(logits)
        blp = jnp.take_along_axis(logp, action[:, None], axis=-1)[:, 0]
        return {"action": action.astype(jnp.int32),
                "behaviour_logprob": blp,
                "value": out.values[:, 0], "cache": out.cache}

    return {"train": train_step, "prefill": prefill_step,
            "serve": serve_step, "optimizer": optimizer, "icfg": icfg}


# ---------------------------------------------------------------------------
# Lowering for the dry-run


def lower_pair(arch: ArchConfig, shape: InputShape, mesh, rules: Rules,
               vtrace_impl: str = "scan", donate: bool = True,
               mixed_precision: bool = False):
    """Lower (not run) the right step for (arch, shape) on mesh.

    Returns (lowered, meta dict)."""
    steps = build_steps(arch, rules, vtrace_impl, mixed_precision)
    specs = bb.backbone_specs(arch, NUM_ACTIONS)
    abstract_params = common.abstract_params(specs)
    if mixed_precision:
        # live params are bf16 leaves; the f32 master sits in opt_state
        abstract_params = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(sd.shape, jnp.bfloat16)
            if jnp.issubdtype(sd.dtype, jnp.floating) else sd,
            abstract_params)
    param_sh = common.param_shardings(specs, rules)
    batch_abs = input_specs(arch, shape)
    batch_ax = batch_logical_axes(arch, shape)
    batch_sh = tree_shardings(batch_abs, batch_ax, rules)
    n_params = common.param_count(specs)
    meta = {"params": n_params}

    with mesh:
        if shape.kind == "train":
            opt_specs = learner_lib.opt_state_specs(specs, steps["icfg"],
                                                    mixed_precision)
            abstract_opt = common.abstract_params(opt_specs)
            opt_sh = common.param_shardings(opt_specs, rules)
            step_sh = NamedSharding(mesh, P())
            fn = jax.jit(
                steps["train"],
                in_shardings=(param_sh, opt_sh, step_sh, batch_sh),
                donate_argnums=(0, 1) if donate else ())
            lowered = fn.lower(abstract_params, abstract_opt,
                               jax.ShapeDtypeStruct((), jnp.int32),
                               batch_abs)
        elif shape.kind == "prefill":
            fn = jax.jit(steps["prefill"],
                         in_shardings=(param_sh, batch_sh))
            lowered = fn.lower(abstract_params, batch_abs)
        else:
            fn = jax.jit(
                steps["serve"],
                in_shardings=(param_sh, batch_sh["token"],
                              batch_sh["cache"], batch_sh["cache_index"],
                              batch_sh["rng"]),
                donate_argnums=(2,) if donate else ())
            lowered = fn.lower(abstract_params, batch_abs["token"],
                               batch_abs["cache"], batch_abs["cache_index"],
                               batch_abs["rng"])
    return lowered, meta
