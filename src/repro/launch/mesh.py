"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — only ``launch/dryrun.py`` sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before init.
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig
from repro.sharding.rules import Rules


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_2d_tp(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """§Perf variant: split the 16-way model axis into 4x4 so head counts
    divisible by 4 (qwen 20H, recurrentgemma/whisper) shard on model_a
    while ffn/vocab use the full 16 = model_a x model_b."""
    shape = (2, 16, 4, 4) if multi_pod else (16, 4, 4)
    axes = (("pod", "data", "model_a", "model_b") if multi_pod
            else ("data", "model_a", "model_b"))
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig) -> jax.sharding.Mesh:
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def make_data_mesh(num_devices: int) -> jax.sharding.Mesh:
    """1-D ``('data',)`` mesh over the first ``num_devices`` local
    devices — the SPMD data-parallel learner topology (batch sharded on
    the trajectory axis, params/opt replicated, gradients psum'd).
    On CPU the device pool is grown with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before
    the first jax import (the ``launch/dryrun.py`` precedent)."""
    avail = len(jax.devices())
    if num_devices < 1 or num_devices > avail:
        raise ValueError(
            f"spmd mesh needs 1..{avail} devices, got {num_devices} "
            f"(on CPU, set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={num_devices} before the first jax import)")
    return jax.make_mesh((num_devices,), ("data",))


def make_rules(mesh: jax.sharding.Mesh, overrides=None) -> Rules:
    return Rules(mesh, overrides)


# TPU v5e hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s per chip
    "hbm_bw": 819e9,             # B/s per chip
    "ici_bw": 50e9,              # B/s per link
}
