"""From-scratch optimizer stack (no optax on this box).

The paper's learner uses RMSProp (momentum 0, tunable epsilon, decay .99)
with global-norm gradient clipping and an (optionally PBT-controlled /
linearly annealed) learning rate. Implemented as composable transforms
with explicit, shardable state pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    # update(grads, state, params, lr) -> (updates, new_state)
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], Tuple[PyTree, PyTree]]


def rmsprop(decay: float = 0.99, eps: float = 0.1,
            momentum: float = 0.0) -> Optimizer:
    """TF-style RMSProp as used by the paper (Appendix D/G)."""

    def init(params):
        ms = jax.tree.map(jnp.zeros_like, params)
        if momentum:
            mom = jax.tree.map(jnp.zeros_like, params)
            return {"ms": ms, "mom": mom}
        return {"ms": ms}

    def update(grads, state, params, lr):
        del params
        ms = jax.tree.map(lambda m, g: decay * m + (1 - decay) * g * g,
                          state["ms"], grads)
        scaled = jax.tree.map(lambda g, m: g * jax.lax.rsqrt(m + eps),
                              grads, ms)
        if momentum:
            mom = jax.tree.map(lambda mo, s: momentum * mo + lr * s,
                               state["mom"], scaled)
            return (jax.tree.map(lambda m: -m, mom), {"ms": ms, "mom": mom})
        return (jax.tree.map(lambda s: -lr * s, scaled), {"ms": ms})

    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        del params
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state["v"], grads)
        tf = t.astype(jnp.float32)
        c1 = 1 - b1 ** tf
        c2 = 1 - b2 ** tf
        upd = jax.tree.map(
            lambda m_, v_: -lr * (m_ / c1) / (jnp.sqrt(v_ / c2) + eps), m, v)
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) +
                                      u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)


def linear_schedule(init_value: float, end_value: float,
                    steps: int) -> Callable[[jax.Array], jax.Array]:
    """The paper anneals the learning rate linearly to 0 over training."""
    if steps <= 0:
        return lambda step: jnp.float32(init_value)

    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / steps, 0.0, 1.0)
        return jnp.float32(init_value + (end_value - init_value) * frac)

    return fn
