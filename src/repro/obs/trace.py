"""Sampled per-trajectory lifecycle tracing across process boundaries.

A sampled trajectory carries a ``trace`` dict of CLOCK_MONOTONIC stamps
in its ``TrajectoryItem`` (and through the serde meta when it crosses a
wire):

    u0 / u1   env unroll start / end (actor side, actor's clock)
    e0 / e1   serde encode start / end (actor side; ``serde.encode_item``
              stamps e1 itself, *after* the payload bytes are built, so
              the stamp can still ride in the header it closes)
    r         receipt into the learner-side policy queue (stamped by
              ``TrajectoryQueue._accept`` — uniform across the inproc,
              shm, and socket transports)

The learner adds its own loop stamps (dequeue, batch collect, train
step, publish) and the recorder folds each sampled item into the seven
lifecycle spans::

    env_unroll -> serde_encode -> transport -> queue_wait
               -> batch_collect -> train_step -> publish

Clock normalization reuses the socket transport's learner-clock
precedent: CLOCK_MONOTONIC is comparable across processes on one box,
so same-box stamps need no shift. When actor and learner clocks
visibly disagree (different machines — the send/receive gap exceeds
``CLOCK_SKEW_S``), the actor-side stamps are shifted so the send
coincides with the learner's receive stamp: every span lands on the
learner's clock, at the cost of folding the (unknowable one-way) wire
latency into the transport span's start.

Export is Chrome trace-event JSON (``{"traceEvents": [...]}``, complete
"X" events, microsecond timestamps) — loadable in Perfetto or
chrome://tracing. Each actor renders as its own process row; the
learner's spans render under the learner row.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Dict, List, Optional

SPAN_NAMES = ("env_unroll", "serde_encode", "transport", "queue_wait",
              "batch_collect", "train_step", "publish")

# gradient-exchange rounds render on their own process row (pid 2):
# hub_wait (round open -> last contribution in), reduce (mean + encode),
# broadcast (fan the mean back out to every live spoke)
EXCHANGE_SPAN_NAMES = ("hub_wait", "reduce", "broadcast")

# same-box monotonic clocks agree to microseconds; a send->receive gap
# beyond this means a different clock domain (another machine)
CLOCK_SKEW_S = 5.0


class TraceRecorder:
    """Collects sampled trajectories' spans; bounded, thread-safe."""

    def __init__(self, max_trajectories: int = 2048):
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._pids_named: set = set()
        self._max = max_trajectories
        self.recorded = 0
        self.dropped = 0

    # ------------------------------------------------------------------

    def _name_pid(self, pid: int, name: str) -> None:
        if pid in self._pids_named:
            return
        self._pids_named.add(pid)
        self._events.append({"name": "process_name", "ph": "M",
                             "pid": pid, "tid": 0,
                             "args": {"name": name}})

    def record_item(self, item, *, dequeued: float, collected: float,
                    step0: float, step1: float, published: float,
                    lag: Optional[int] = None) -> None:
        """Fold one sampled item (its actor-side ``trace`` stamps plus
        the learner's loop stamps, all seconds CLOCK_MONOTONIC) into
        trace events. Safe to call with partial stamps — missing actor
        stamps degrade to zero-length spans, never to an exception."""
        tr = getattr(item, "trace", None)
        if tr is None:
            return
        with self._lock:
            if self.recorded >= self._max:
                self.dropped += 1
                return
            self.recorded += 1

            r = tr.get("r", dequeued)
            u1 = tr.get("u1", r)
            u0 = tr.get("u0", u1)
            e0 = tr.get("e0", u1)
            e1 = tr.get("e1", e0)
            # learner-clock normalization: shift actor stamps only when
            # the clocks visibly disagree (cross-machine)
            off = (r - e1) if abs(r - e1) > CLOCK_SKEW_S else 0.0
            u0, u1, e0, e1 = (t + off for t in (u0, u1, e0, e1))

            actor_pid = 1000 + int(item.actor_id)
            self._name_pid(actor_pid, f"actor-{item.actor_id}")
            self._name_pid(1, "learner")

            spans = (
                ("env_unroll", actor_pid, u0, u1),
                ("serde_encode", actor_pid, e0, e1),
                ("transport", actor_pid, e1, r),
                ("queue_wait", 1, r, dequeued),
                ("batch_collect", 1, dequeued, collected),
                ("train_step", 1, collected if step0 is None else step0,
                 step1),
                ("publish", 1, step1, published),
            )
            args = {"actor_id": int(item.actor_id),
                    "param_version": int(item.param_version)}
            if lag is not None:
                args["lag"] = int(lag)
            for name, pid, t0, t1 in spans:
                self._events.append({
                    "name": name, "ph": "X", "pid": pid, "tid": 0,
                    "ts": t0 * 1e6,
                    "dur": max(0.0, (t1 - t0) * 1e6),
                    "args": args,
                })

    def record_exchange_round(self, round_idx: int, *, enter: float,
                              gathered: float, reduced: float,
                              done: float) -> None:
        """Fold one gradient-exchange round (hub-side CLOCK_MONOTONIC
        stamps) into hub_wait -> reduce -> broadcast spans on the
        ``exchange`` row. A failover round shows up as an oversized
        hub_wait span followed by a gap in the round numbering."""
        with self._lock:
            if self.recorded >= self._max:
                self.dropped += 1
                return
            self.recorded += 1
            self._name_pid(2, "exchange")
            args = {"round": int(round_idx)}
            for name, t0, t1 in (("hub_wait", enter, gathered),
                                 ("reduce", gathered, reduced),
                                 ("broadcast", reduced, done)):
                self._events.append({
                    "name": name, "ph": "X", "pid": 2, "tid": 0,
                    "ts": t0 * 1e6,
                    "dur": max(0.0, (t1 - t0) * 1e6),
                    "args": args,
                })

    # ------------------------------------------------------------------

    def chrome_events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def export(self, path: str) -> int:
        """Write ``{"traceEvents": [...]}``; returns the number of
        sampled trajectories recorded."""
        with self._lock:
            doc = {"traceEvents": list(self._events),
                   "displayTimeUnit": "ms"}
            n = self.recorded
        with open(path, "w") as f:
            json.dump(doc, f)
            f.write("\n")
        return n
