"""Stdlib-only metrics endpoint next to the learner.

``MetricsServer`` runs a ``ThreadingHTTPServer`` on a daemon thread and
serves three routes off one ``snapshot_fn`` (the learner's
``telemetry_snapshot``, or the group parent's merged view):

  /metrics     the snapshot flattened to Prometheus text exposition
               format. Nested dicts become underscore-joined metric
               names; integer-keyed histograms become one sample per
               bucket (``repro_lag_hist{bucket="3"} 17``); the group's
               ``learners.learner_<k>.*`` subtrees become a
               ``learner="k"`` label, so one port exposes per-learner
               queue depth, fps, reconnects, torn tails for the fleet.
  /healthz     ok / degraded / unhealthy derived from the snapshot:
               unhealthy (HTTP 503) on lost-learner conditions (a
               spoke's hub connection gone, dead learners in the hub's
               view, a supervisor whose restart budget is exhausted);
               degraded (HTTP 200, status field says so) on
               loss/instability counters (drops, reconnects, torn
               tails, stale gradients, decode errors) and while a
               supervised restart or hub failover is in flight.
  /telemetry   the snapshot as JSON, verbatim.

The server must never take down the run it observes: snapshot or
rendering failures return HTTP 500 with the error text, and the
handler logs nothing to stderr.
"""
from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, List, Optional, Tuple

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")
_LEARNER_RE = re.compile(r"^learner_(\d+)$")

# degraded when any of these counters is nonzero anywhere in the tree
_DEGRADED_KEYS = ("dropped", "reconnects", "torn_tails", "stale_dropped",
                  "discarded", "decode_errors", "drain_errors",
                  "partial_rounds", "hub_gone_retries")


def _metric_name(path: List[str]) -> str:
    return "repro_" + _NAME_RE.sub("_", "_".join(path))


def _fmt(v: Any) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _is_bucket_dict(d: Dict) -> bool:
    if not d:
        return False
    try:
        return all(int(k) == int(k) for k in d) and \
            all(isinstance(v, (int, float)) for v in d.values())
    except (TypeError, ValueError):
        return False


def render_prometheus(snap: Dict[str, Any]) -> str:
    """Flatten a telemetry snapshot into Prometheus text format.
    Strings, lists, and None are skipped (they are labels in spirit,
    not samples); ``learners.learner_<k>`` levels become a label."""
    lines: List[str] = []

    def walk(node: Any, path: List[str], labels: List[Tuple[str, str]]):
        if isinstance(node, dict):
            if _is_bucket_dict(node) and path:
                for k in sorted(node, key=lambda x: int(x)):
                    emit(path, labels + [("bucket", str(k))], node[k])
                return
            for k, v in node.items():
                k = str(k)
                m = _LEARNER_RE.match(k)
                if m and path and path[-1] == "learners":
                    walk(v, path[:-1], labels + [("learner", m.group(1))])
                else:
                    # dots inside a key are producer namespacing
                    # ("learner.lag_hist"), the same separator as
                    # nesting — split them so names come out uniform
                    walk(v, path + k.split("."), labels)
            return
        if isinstance(node, (bool, int, float)):
            emit(path, labels, node)
        # str / list / None: not a sample

    def emit(path: List[str], labels: List[Tuple[str, str]], v: Any):
        try:
            name = _metric_name(path)
            label_s = ""
            if labels:
                label_s = "{" + ",".join(
                    f'{k}="{val}"' for k, val in labels) + "}"
            lines.append(f"{name}{label_s} {_fmt(v)}")
        except (TypeError, ValueError, OverflowError):
            pass

    walk(snap, [], [])
    return "\n".join(lines) + "\n"


def health(snap: Dict[str, Any]) -> Tuple[int, Dict[str, Any]]:
    """(http status, body) — unhealthy beats degraded beats ok."""
    bad: List[str] = []
    deg: List[str] = []

    def walk(node: Any, path: str):
        if not isinstance(node, dict):
            return
        for k, v in node.items():
            here = f"{path}.{k}" if path else str(k)
            if k == "hub_gone" and v:
                bad.append(here)
            elif k == "dead_learners" and v:
                bad.append(f"{here}={v}")
            elif k == "replicas_identical" and v is False:
                bad.append(here)
            elif k == "restarts_exhausted" and v:
                bad.append(f"{here}={v}")
            elif k in ("restart_in_flight", "failover_in_flight",
                       "degraded_solo") and v:
                deg.append(here)
            elif k in _DEGRADED_KEYS:
                n = v if isinstance(v, (int, float)) else len(v or ())
                if n:
                    deg.append(f"{here}={int(n)}")
            if isinstance(v, dict):
                walk(v, here)

    walk(snap, "")
    if bad:
        return 503, {"status": "unhealthy", "reasons": bad,
                     "degraded": deg}
    if deg:
        return 200, {"status": "degraded", "reasons": deg}
    return 200, {"status": "ok"}


class MetricsServer:
    """Background HTTP server over one zero-arg ``snapshot_fn``."""

    def __init__(self, snapshot_fn: Callable[[], Dict[str, Any]], *,
                 host: str = "127.0.0.1", port: int = 0):
        self._snapshot_fn = snapshot_fn
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):        # keep stderr clean
                pass

            def _send(self, code: int, body: str, ctype: str):
                data = body.encode("utf-8")
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def do_GET(self):
                route = self.path.split("?", 1)[0]
                try:
                    if route == "/metrics":
                        snap = outer._snapshot_fn()
                        self._send(200, render_prometheus(snap),
                                   "text/plain; version=0.0.4")
                    elif route == "/healthz":
                        code, body = health(outer._snapshot_fn())
                        self._send(code, json.dumps(body),
                                   "application/json")
                    elif route == "/telemetry":
                        snap = outer._snapshot_fn()
                        self._send(200, json.dumps(snap, default=float),
                                   "application/json")
                    else:
                        self._send(404, "not found\n", "text/plain")
                except BrokenPipeError:
                    pass
                except Exception as e:      # observing must not crash
                    try:
                        self._send(500, f"snapshot failed: {e!r}\n",
                                   "text/plain")
                    except OSError:
                        pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.address: Tuple[str, int] = \
            self._httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-http", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
