"""A registry of named metrics instruments plus pull-time producers.

Design constraints, in order:

  hot-path cost   every writer that matters (queue put/get, socket
                  frame receive, inference flush, learner update) is
                  already serialized by its own lock. Instruments
                  therefore do NOT take a lock per write — ``inc`` is a
                  plain ``+=`` and the *caller's* existing lock is the
                  write serialization, exactly as the raw ``self.pushed
                  += 1`` counters worked before the registry existed.
  torn reads      ``collect()`` may run concurrently with writers (the
                  /metrics HTTP thread against the learner loop). Ints
                  and floats are replaced atomically under the GIL, so
                  scalar reads are never torn; histogram dict copies
                  can race a concurrent insert, so they retry.
  one data source the end-of-run ``telemetry_snapshot()`` and the live
                  ``/metrics`` endpoint both read ``collect()`` — a
                  counter cannot drift between the two because there is
                  only one of it.

*Producers* cover state that already has an owner with a snapshot
method (a transport's wire counters, the inference service, a gradient
exchange): ``register_producer("queue", q.snapshot)`` makes
``collect()["queue"]`` that snapshot, evaluated at pull time.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Dict, Optional


class Counter:
    """A monotonically increasing (or explicitly adjusted) integer.
    Writers serialize themselves (see module docstring)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """A point-in-time value; ``set`` replaces it atomically."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class IntHistogram:
    """An integer-keyed histogram: exactly the ``collections.Counter``
    shape the runtime's lag / batch-size histograms always used. The
    ``counts`` Counter is exposed directly so existing code paths
    (``hist[k] += 1``, ``dict(sorted(hist.items()))``, ``max(hist)``)
    keep working on the registry's storage — the hot-path write IS the
    registry write."""

    __slots__ = ("name", "counts")

    def __init__(self, name: str):
        self.name = name
        self.counts: collections.Counter = collections.Counter()

    def observe(self, k: int, n: int = 1) -> None:
        self.counts[k] += n


def safe_copy(d: Dict) -> Dict:
    """Copy a dict that a writer may be growing concurrently: a plain
    ``dict(d)`` can raise RuntimeError mid-iteration, so retry a few
    times and fall back to an item-by-item best effort."""
    for _ in range(4):
        try:
            return dict(d)
        except RuntimeError:
            continue
    out = {}
    for k in list(d):
        try:
            out[k] = d[k]
        except KeyError:
            pass
    return out


class Registry:
    """Create-or-get instruments by name, plus pull-time producers.

    The name is the identity: asking twice for ``counter("q.pushed")``
    returns the same object, so a component and its telemetry reader
    never hold different counters. Asking for an existing name with a
    different instrument type raises."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[str, Any] = {}
        self._producers: Dict[str, Callable[[], Optional[Dict]]] = {}

    # ------------------------------------------------------------------

    def _get(self, name: str, cls):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name)
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def int_histogram(self, name: str) -> IntHistogram:
        return self._get(name, IntHistogram)

    def register_producer(self, name: str,
                          fn: Callable[[], Optional[Dict]]) -> None:
        """``collect()[name]`` becomes ``fn()`` evaluated at pull time.
        A producer returning None is omitted from the collection (the
        hook for optional sections like ``inference``). Re-registering
        a name replaces the producer — components are rebuilt per run."""
        with self._lock:
            self._producers[name] = fn

    # ------------------------------------------------------------------

    def collect(self) -> Dict[str, Any]:
        """One flat pull of everything: instrument values by name,
        producer dicts by name. Producer exceptions are captured as an
        ``error`` entry instead of killing the telemetry reader — a
        metrics pull must never take down the run it is observing."""
        with self._lock:
            instruments = list(self._instruments.items())
            producers = list(self._producers.items())
        out: Dict[str, Any] = {}
        for name, inst in instruments:
            if isinstance(inst, IntHistogram):
                out[name] = safe_copy(inst.counts)
            else:
                out[name] = inst.value
        for name, fn in producers:
            try:
                val = fn()
            except Exception as e:
                val = {"error": repr(e)}
            if val is not None:
                out[name] = val
        return out
