"""Time-series sink and profiling hooks.

``JsonlSink`` appends the telemetry snapshot to a JSONL file every
``interval_s`` from a daemon thread — the poor operator's Prometheus:
a run leaves behind a greppable time series (one JSON object per line,
wall-clock stamped) even when nobody was curling /metrics.

``ProfileHook`` wraps ``jax.profiler`` around a chosen train-step
window (``--profile-steps A:B``): the trace starts before step A's
update and stops after step B's, producing a TensorBoard-loadable
profile directory. Failures (profiler unavailable, trace dir not
writable) disable the hook with a one-line note instead of killing
training.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple


class JsonlSink:
    """Periodic snapshot dumps: one JSON object per line."""

    def __init__(self, path: str,
                 snapshot_fn: Callable[[], Dict[str, Any]],
                 interval_s: float = 5.0):
        self.path = path
        self._snapshot_fn = snapshot_fn
        self._interval_s = max(0.05, interval_s)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.lines_written = 0

    def _write_one(self, f) -> None:
        try:
            snap = self._snapshot_fn()
        except Exception as e:
            snap = {"error": repr(e)}
        f.write(json.dumps({"t": time.time(), "telemetry": snap},
                           default=float))
        f.write("\n")
        f.flush()
        self.lines_written += 1

    def _run(self) -> None:
        with open(self.path, "a") as f:
            while not self._stop.wait(self._interval_s):
                self._write_one(f)
            self._write_one(f)      # final state on shutdown

    def start(self) -> "JsonlSink":
        self._thread = threading.Thread(target=self._run,
                                        name="telemetry-sink",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)


def parse_profile_steps(spec: str) -> Tuple[int, int]:
    """``"A:B"`` -> (A, B), inclusive update-index window, A <= B."""
    a, sep, b = spec.partition(":")
    if not sep:
        raise ValueError(f"--profile-steps wants A:B, got {spec!r}")
    lo, hi = int(a), int(b)
    if lo < 0 or hi < lo:
        raise ValueError(f"bad profile window {spec!r} (need 0<=A<=B)")
    return lo, hi


class ProfileHook:
    """Start/stop ``jax.profiler`` around updates [A, B]."""

    def __init__(self, steps: str, out_dir: str):
        self.lo, self.hi = parse_profile_steps(steps)
        self.out_dir = out_dir
        self.active = False
        self.done = False

    def on_step(self, next_update: int) -> None:
        """Call once per loop iteration with the index of the update
        about to run (0-based ``learner.updates``)."""
        if self.done:
            return
        if not self.active and self.lo <= next_update <= self.hi:
            try:
                import jax
                jax.profiler.start_trace(self.out_dir)
                self.active = True
                print(f"[obs] jax.profiler tracing updates "
                      f"[{self.lo}, {self.hi}] -> {self.out_dir}",
                      flush=True)
            except Exception as e:
                print(f"[obs] profiling disabled: {e!r}", flush=True)
                self.done = True
        elif self.active and next_update > self.hi:
            self.stop()

    def stop(self) -> None:
        if self.active:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception as e:
                print(f"[obs] profiler stop failed: {e!r}", flush=True)
            self.active = False
        self.done = True
