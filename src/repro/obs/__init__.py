"""Flight-recorder observability for the distributed runtime.

Four pieces, all stdlib-only at import time (the distributed modules
import this package before paying the jax import, like the transports):

  metrics   a thread-compatible registry of named counters / gauges /
            integer histograms plus pull-time *producers*. The hot-path
            modules (tqueue, socket transport, inference service,
            learner) write their existing counters through registry
            instruments, and ``Learner.telemetry_snapshot`` /
            ``group.merge_telemetry`` derive the pinned telemetry key
            sets from a registry ``collect()`` — live metrics and
            end-of-run telemetry are one data source, not two.
  trace     sampled per-trajectory lifecycle spans (env unroll -> serde
            encode -> transport -> queue wait -> batch collect -> train
            step -> publish), stamped across process/socket boundaries
            and normalized to the learner's clock, exported as Chrome
            trace-event JSON (loadable in Perfetto / chrome://tracing).
  http      a background stdlib HTTP server next to the learner serving
            ``/metrics`` (Prometheus text format), ``/healthz``
            (ok / degraded / unhealthy), and ``/telemetry`` (live JSON).
  sink      periodic JSONL time-series dumps of the telemetry snapshot,
            plus the ``--profile-steps A:B`` hook wrapping
            ``jax.profiler`` around chosen train steps.

``ObsConfig`` is the single knob bag the CLI builds and the runtime
threads through ``run_async_training(obs=...)`` /
``run_group_training(obs=...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.obs.metrics import Counter, Gauge, IntHistogram, Registry  # noqa: F401
from repro.obs.trace import SPAN_NAMES, TraceRecorder  # noqa: F401


@dataclasses.dataclass
class ObsConfig:
    """What the operator asked to observe. All fields default to off;
    an all-defaults ObsConfig still enables phase timing (it only
    exists because someone passed ``obs=``)."""

    metrics_port: Optional[int] = None      # None = no HTTP server
    metrics_host: str = "127.0.0.1"
    trace_path: Optional[str] = None        # Chrome trace JSON out
    trace_every: int = 64                   # sample every Nth unroll/actor
    profile_steps: Optional[str] = None     # "A:B" train-step window
    profile_dir: str = "/tmp/repro-profile"
    sink_path: Optional[str] = None         # JSONL time series out
    sink_interval_s: float = 5.0
    telemetry_interval_s: float = 2.0       # child->parent pipe shipping
    # set by the runtime once the HTTP server binds (port 0 resolves
    # here), so tests and log lines can discover the real address
    bound_address: Optional[Tuple[str, int]] = None
