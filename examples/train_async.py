"""Async quickstart: the paper's actual architecture — actors decoupled
from the learner — running as real threads in one process.

Two actor threads each drive their own batch of `catch` envs with a
jitted unroll (the dispatch drops the GIL, so they overlap the learner);
trajectories flow through a bounded backpressured queue; the learner
stacks up to 4 of them per update (dynamic batching) and publishes params
through a versioned store. Policy lag is *measured* per trajectory — watch
the lag histogram in the final telemetry, it is the off-policy gap that
V-trace is correcting.

This is the thread backend over the zero-copy in-process transport; see
``examples/train_multiproc.py`` for the same run with actor *processes*
shipping serialized trajectory buffers over the shm transport.

A second, shorter run switches the same actors to **inference mode**
(paper §3.1's dynamic batching): no per-actor params — every actor
steps envs on the host and submits its per-step observation batch to
one InferenceService that batches across actors into power-of-two
buckets on the learner's device. Watch the service telemetry: the
batch-size histogram, full/ready/timeout flush counts, and queue-wait
quantiles are the observable effect of the batching knobs.

  PYTHONPATH=src python examples/train_async.py
"""
import json

from repro.configs.base import ImpalaConfig
from repro.configs.registry import get_smoke_config
from repro.data.envs import make_catch
from repro.distributed import run_async_training


def main():
    env = make_catch()
    arch = get_smoke_config("impala-shallow").replace(image_hw=env.image_hw)
    cfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=20,
                       learning_rate=6e-4, entropy_cost=0.003,
                       rmsprop_eps=0.01)

    def log(step, params, metrics, snapshot_fn):
        if step % 100 == 0:
            tel = snapshot_fn()
            print(f"update {step}: loss={float(metrics['loss/total']):.2f} "
                  f"lag(mean)={tel['lag']['mean']:.2f} "
                  f"queue_occ={tel['queue']['mean_occupancy']:.1f} "
                  f"fps={tel['frames_per_sec']:.0f}")

    tracker, metrics, tel = run_async_training(
        env, cfg, num_envs=32, steps=400, num_actors=2,
        queue_capacity=8, queue_policy="block", max_batch_trajs=4,
        seed=0, arch=arch, on_update=log)

    print(f"return(100) = {tracker.mean_return():.3f} "
          f"(optimal 1.0, random ~ -0.6)")
    print("measured lag histogram:", json.dumps(tel["lag"]["hist"]))
    print("queue:", json.dumps(tel["queue"]))
    assert tel["lag"]["max"] > 0, "async run must show real policy lag"

    print("\n-- same actors, inference mode: one dynamic-batching "
          "service forward instead of per-actor unrolls --")
    tracker2, _, tel2 = run_async_training(
        env, cfg, num_envs=32, steps=200, num_actors=2,
        actor_mode="inference", queue_capacity=8, queue_policy="block",
        max_batch_trajs=4, seed=0, arch=arch)
    inf = tel2["inference"]
    print(f"return(100) = {tracker2.mean_return():.3f} after "
          f"{tel2['learner_updates']} updates")
    print(f"service: {inf['flushes']} flushes "
          f"(full={inf['flush_full']} ready={inf['flush_ready']} "
          f"timeout={inf['flush_timeout']}), "
          f"mean batch {inf['mean_batch']:.2f}")
    print("batch-size histogram:", json.dumps(inf["batch_size_hist"]))
    print(f"queue wait p50/p95 = {inf['queue_wait_ms_p50']:.2f}/"
          f"{inf['queue_wait_ms_p95']:.2f} ms")
    assert tel2["lag"]["measured"] > 0, "inference mode must measure lag"
    print("done.")


if __name__ == "__main__":
    main()
