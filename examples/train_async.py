"""Async quickstart: the paper's actual architecture — actors decoupled
from the learner — running as real threads in one process.

Two actor threads each drive their own batch of `catch` envs with a
jitted unroll (the dispatch drops the GIL, so they overlap the learner);
trajectories flow through a bounded backpressured queue; the learner
stacks up to 4 of them per update (dynamic batching) and publishes params
through a versioned store. Policy lag is *measured* per trajectory — watch
the lag histogram in the final telemetry, it is the off-policy gap that
V-trace is correcting.

  PYTHONPATH=src python examples/train_async.py
"""
import json

from repro.configs.base import ImpalaConfig
from repro.configs.registry import get_smoke_config
from repro.data.envs import make_catch
from repro.distributed import run_async_training


def main():
    env = make_catch()
    arch = get_smoke_config("impala-shallow").replace(image_hw=env.image_hw)
    cfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=20,
                       learning_rate=6e-4, entropy_cost=0.003,
                       rmsprop_eps=0.01)

    def log(step, params, metrics, snapshot_fn):
        if step % 100 == 0:
            tel = snapshot_fn()
            print(f"update {step}: loss={float(metrics['loss/total']):.2f} "
                  f"lag(mean)={tel['lag']['mean']:.2f} "
                  f"queue_occ={tel['queue']['mean_occupancy']:.1f} "
                  f"fps={tel['frames_per_sec']:.0f}")

    tracker, metrics, tel = run_async_training(
        env, cfg, num_envs=32, steps=400, num_actors=2,
        queue_capacity=8, queue_policy="block", max_batch_trajs=4,
        seed=0, arch=arch, on_update=log)

    print(f"return(100) = {tracker.mean_return():.3f} "
          f"(optimal 1.0, random ~ -0.6)")
    print("measured lag histogram:", json.dumps(tel["lag"]["hist"]))
    print("queue:", json.dumps(tel["queue"]))
    assert tel["lag"]["max"] > 0, "async run must show real policy lag"
    print("done.")


if __name__ == "__main__":
    main()
