"""End-to-end driver: train a ~100M-parameter transformer policy with the
full IMPALA stack for a few hundred steps on CPU.

The backbone is a scaled-down qwen1.5-family decoder (~100M params with
the env-sized vocab); actors run the decode/KV-cache path, the learner
runs the full-trajectory V-trace path — the same code paths the assigned
production configs lower on the 512-chip mesh.

  PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ImpalaConfig
from repro.configs.registry import get_config
from repro.core import actor as actor_lib
from repro.core import learner as learner_lib
from repro.core.metrics import EpisodeTracker
from repro.core.queue import LagController, TrajectoryQueue
from repro.data.envs import make_env
from repro.models import backbone as bb
from repro.models import common


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--num-envs", type=int, default=8)
    p.add_argument("--unroll", type=int, default=16)
    p.add_argument("--env", default="bandit")
    args = p.parse_args()

    env = make_env(args.env)
    # ~100M-parameter decoder in the qwen1.5 family
    arch = get_config("qwen1.5-4b").replace(
        num_layers=10, d_model=896, num_heads=14, num_kv_heads=2,
        d_ff=3072, vocab_size=max(4096, env.vocab_size), remat=False)
    cfg = ImpalaConfig(num_actions=env.num_actions,
                       unroll_length=args.unroll, learning_rate=3e-4,
                       entropy_cost=0.005, rmsprop_eps=0.01, policy_lag=1)

    specs = bb.backbone_specs(arch, env.num_actions)
    params = common.init_params(specs, jax.random.key(0))
    n = common.param_count(specs)
    print(f"backbone: {arch.name}-100m  params={n/1e6:.1f}M")
    assert n > 80e6, n

    init_fn, unroll = actor_lib.build_actor(env, arch, cfg, args.num_envs)
    train_step, opt = learner_lib.build_train_step(arch, cfg,
                                                   env.num_actions)
    train_step = jax.jit(train_step)
    opt_state = opt.init(params)
    carry = init_fn(jax.random.key(1))
    lag = LagController(cfg.policy_lag, params)
    queue = TrajectoryQueue(8)
    tracker = EpisodeTracker(args.num_envs)

    t0 = time.time()
    for step in range(args.steps):
        carry, traj = unroll(lag.actor_params(), carry)
        queue.put(traj)
        tracker.update(np.asarray(traj["rewards"]), np.asarray(traj["done"]))
        params, opt_state, m = train_step(params, opt_state,
                                          jnp.int32(step), queue.get())
        lag.on_update(params)
        if (step + 1) % 20 == 0:
            fps = (step + 1) * args.num_envs * args.unroll / (time.time() - t0)
            print(f"step {step+1:4d} return(100)={tracker.mean_return():7.3f}"
                  f" loss={float(m['loss/total']):9.2f} fps={fps:6.0f}")
    print(f"final return(100) = {tracker.mean_return():.3f}")


if __name__ == "__main__":
    main()
