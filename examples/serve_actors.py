"""Serving-side demo: batched actor inference with the decode/KV-cache
path (the IMPALA actor hot loop), plus a prefill->decode handoff — the
same ``prefill_step``/``serve_step`` the production shapes lower.

  PYTHONPATH=src python examples/serve_actors.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import backbone as bb
from repro.models import common

A = 18
B = 16          # concurrent actor requests (dynamic-batching analogue)
CTX = 64        # context each request carries


def main():
    cfg = get_smoke_config("mistral-nemo-12b").replace(vocab_size=4096)
    specs = bb.backbone_specs(cfg, A)
    params = common.init_params(specs, jax.random.key(0))
    print(f"backbone {cfg.name} params={common.param_count(specs):,}")

    # 1) prefill: every actor ingests its 64-token context in one pass
    toks = jax.random.randint(jax.random.key(1), (B, CTX), 0, cfg.vocab_size)
    prefill = jax.jit(lambda p, t: bb.apply_prefill(p, {"tokens": t}, cfg, A))
    out = prefill(params, toks)
    cache = out.cache
    print(f"prefill: logits {out.policy_logits.shape}, cache ready")

    # 2) decode loop: one action per step per actor, batched
    serve = jax.jit(lambda p, tok, c, i: bb.apply_decode(p, tok, c, i, cfg, A))
    tok = toks[:, -1:]
    key = jax.random.key(2)
    t0 = time.time()
    n_steps = 32
    for i in range(n_steps):
        out = serve(params, tok, cache, jnp.int32(CTX + i))
        cache = out.cache
        key, k = jax.random.split(key)
        action = jax.random.categorical(k, out.policy_logits[:, 0])
        # environment would consume `action` and return the next obs;
        # here we feed a synthetic next token
        tok = (action[:, None] % cfg.vocab_size).astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode: {n_steps} steps x {B} actors = {n_steps*B} actions "
          f"in {dt:.2f}s ({n_steps*B/dt:.0f} actions/s)")
    print(f"values sample: {np.asarray(out.values[:4, 0])}")


if __name__ == "__main__":
    main()
