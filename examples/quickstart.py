"""Quickstart: train the paper's shallow conv-LSTM agent on `catch` with
the full IMPALA pipeline (decoupled actors + V-trace learner) in ~2 min
on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ImpalaConfig
from repro.configs.registry import get_smoke_config
from repro.core import actor as actor_lib
from repro.core import learner as learner_lib
from repro.core.metrics import EpisodeTracker
from repro.core.queue import LagController
from repro.data.envs import make_catch
from repro.models import backbone as bb
from repro.models import common


def main():
    env = make_catch()
    arch = get_smoke_config("impala-shallow").replace(image_hw=env.image_hw)
    cfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=20,
                       learning_rate=6e-4, entropy_cost=0.003,
                       rmsprop_eps=0.01, policy_lag=1)

    specs = bb.backbone_specs(arch, env.num_actions)
    params = common.init_params(specs, jax.random.key(0))
    print(f"params: {common.param_count(specs):,}")

    init_fn, unroll = actor_lib.build_actor(env, arch, cfg, num_envs=32)
    train_step, opt = learner_lib.build_train_step(arch, cfg,
                                                   env.num_actions)
    train_step = jax.jit(train_step)
    opt_state = opt.init(params)
    carry = init_fn(jax.random.key(1))
    lag = LagController(cfg.policy_lag, params)  # actors run stale params
    tracker = EpisodeTracker(32)

    for step in range(500):
        carry, traj = unroll(lag.actor_params(), carry)   # actors
        tracker.update(np.asarray(traj["rewards"]),
                       np.asarray(traj["done"]))
        params, opt_state, m = train_step(params, opt_state,
                                          jnp.int32(step), traj)  # learner
        lag.on_update(params)
        if (step + 1) % 100 == 0:
            print(f"step {step+1}: return(100) = "
                  f"{tracker.mean_return():.3f}  "
                  f"(optimal 1.0, random ~ -0.6)")
    assert tracker.mean_return() > 0.0, "should beat random"
    print("done.")


if __name__ == "__main__":
    main()
