"""Multi-process actors: the paper's deployment shape on one machine.

Two actor *processes* (own interpreter, own env batch, own jit cache —
no GIL shared with the learner) act `catch` and ship serde-encoded
trajectory buffers over the shm transport; the learner drains them with
dynamic batching and publishes parameters back through the store's
serialized subscribe path (encoded once per version, pulled by version
over a pipe). Same loop body, same RNG streams, same telemetry as the
thread backend — only the transport changed. That is the point.

  PYTHONPATH=src python examples/train_multiproc.py
"""
import json

from repro.configs.base import ImpalaConfig
from repro.configs.registry import get_smoke_config
from repro.data.envs import make_catch
from repro.distributed import run_async_training


def main():
    env = make_catch()
    arch = get_smoke_config("impala-shallow").replace(image_hw=env.image_hw)
    cfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=20,
                       learning_rate=6e-4, entropy_cost=0.003,
                       rmsprop_eps=0.01)

    def log(step, params, metrics, snapshot_fn):
        if step % 100 == 0:
            tel = snapshot_fn()
            q = tel["queue"]
            print(f"update {step}: loss={float(metrics['loss/total']):.2f} "
                  f"lag(mean)={tel['lag']['mean']:.2f} "
                  f"wire_mb={q['wire_bytes'] / 1e6:.1f} "
                  f"fps={tel['frames_per_sec']:.0f}")

    tracker, metrics, tel = run_async_training(
        "catch", cfg, num_envs=32, steps=400, num_actors=2,
        actor_backend="process", transport="shm",
        queue_capacity=8, queue_policy="block", max_batch_trajs=4,
        seed=0, arch=arch, on_update=log)

    print(f"return(100) = {tracker.mean_return():.3f} "
          f"(optimal 1.0, random ~ -0.6)")
    print("measured lag histogram:", json.dumps(tel["lag"]["hist"]))
    print("transport:", json.dumps(tel["queue"]))
    assert tel["queue"]["wire_received"] > 0, "trajectories must cross the wire"
    assert tel["lag"]["max"] > 0, "async run must show real policy lag"
    print("done.")


if __name__ == "__main__":
    main()
