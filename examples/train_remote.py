"""Remote actors over TCP: the paper's cross-machine deployment shape.

The launcher pair. Terminal 1 — the learner listens and waits:

  PYTHONPATH=src python examples/train_remote.py learner --port 41017

Terminal 2 (any machine that can reach it) — actors dial in, receive
the ENTIRE run configuration (env, architecture, seed, actor id, mode)
in the connection handshake, and start acting; they need no flags
beyond the address:

  PYTHONPATH=src python examples/train_remote.py actor \\
      --connect 127.0.0.1:41017 --num 2

A single-terminal demo (the learner spawns its own loopback "remote"
actors — the same code path, one box):

  PYTHONPATH=src python examples/train_remote.py demo

Trajectories travel as length-prefixed CRC-checked frames; parameters
flow back version-gated over each actor's control connection; a severed
link reconnects with backoff and loses at most the in-flight
trajectory. Run ``demo --mode inference`` to serve actions from the
learner-side InferenceService instead — then the remote machines hold
no parameters at all.
"""
import argparse
import json

STEPS = 400


def _parse(spec, default_host="127.0.0.1"):
    host, sep, port = spec.rpartition(":")
    if not sep or not port.isdigit():
        raise SystemExit(f"expected HOST:PORT, got {spec!r}")
    return (host or default_host, int(port))


def _train(listen_addr, spawn_remote, num_actors, mode):
    from repro.configs.base import ImpalaConfig
    from repro.configs.registry import get_smoke_config
    from repro.data.envs import make_catch
    from repro.distributed import run_async_training

    env = make_catch()
    arch = get_smoke_config("impala-shallow").replace(
        image_hw=env.image_hw)
    cfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=20,
                       learning_rate=6e-4, entropy_cost=0.003,
                       rmsprop_eps=0.01)

    def log(step, params, metrics, snapshot_fn):
        if step % 100 == 0:
            tel = snapshot_fn()
            q = tel["queue"]
            print(f"update {step}: loss={float(metrics['loss/total']):.2f} "
                  f"lag(mean)={tel['lag']['mean']:.2f} "
                  f"net={q['bytes_per_sec'] / 1e6:.2f}MB/s "
                  f"reconnects={q['reconnects']} "
                  f"fps={tel['frames_per_sec']:.0f}")

    tracker, metrics, tel = run_async_training(
        "catch", cfg, num_envs=32, steps=STEPS, num_actors=num_actors,
        actor_backend="remote", actor_mode=mode, transport="socket",
        listen_addr=listen_addr, spawn_remote=spawn_remote,
        queue_capacity=8, queue_policy="block", max_batch_trajs=4,
        seed=0, arch=arch, on_update=log)

    q = tel["queue"]
    print(f"return(100) = {tracker.mean_return():.3f} "
          f"(optimal 1.0, random ~ -0.6)")
    print(f"socket: {q['frames_in']} frames, {q['bytes_in'] / 1e6:.1f}MB, "
          f"{q['reconnects']} reconnects, {q['torn_tails']} torn tails, "
          f"{q['decode_errors']} decode errors")
    print("per-actor:", json.dumps(q["per_actor"], default=float))
    assert q["frames_in"] > 0, "trajectories must cross the socket"
    assert q["decode_errors"] == 0, "no torn frame may reach the learner"
    print("done.")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)
    pl = sub.add_parser("learner", help="listen and wait for actors")
    pl.add_argument("--port", type=int, default=41017)
    pl.add_argument("--host", default="0.0.0.0")
    pl.add_argument("--actors", type=int, default=2,
                    help="how many remote actors to expect")
    pl.add_argument("--mode", default="unroll",
                    choices=["unroll", "inference"])
    pa = sub.add_parser("actor", help="dial a learner and act")
    pa.add_argument("--connect", required=True, metavar="HOST:PORT")
    pa.add_argument("--num", type=int, default=1,
                    help="actor processes this machine contributes")
    pd = sub.add_parser("demo", help="single-terminal loopback demo")
    pd.add_argument("--actors", type=int, default=2)
    pd.add_argument("--mode", default="unroll",
                    choices=["unroll", "inference"])
    args = p.parse_args()

    if args.cmd == "learner":
        _train((args.host, args.port), spawn_remote=False,
               num_actors=args.actors, mode=args.mode)
    elif args.cmd == "actor":
        import multiprocessing as mp
        addr = _parse(args.connect)
        if args.num == 1:
            import os
            from repro.distributed import remote_actor_main
            err = remote_actor_main(addr)
            if err:
                raise SystemExit(err)
            print("learner said stop; exiting cleanly")
            os._exit(0)     # skip C++ teardown (see remote_actor_child)
        else:
            from repro.distributed.netserve import remote_actor_child
            ctx = mp.get_context("spawn")
            stop = ctx.Event()
            procs = [ctx.Process(target=remote_actor_child,
                                 args=(addr, stop))
                     for _ in range(args.num)]
            for proc in procs:
                proc.start()
            for proc in procs:
                proc.join()
    else:
        _train(None, spawn_remote=True, num_actors=args.actors,
               mode=args.mode)


if __name__ == "__main__":
    main()
