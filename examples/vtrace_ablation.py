"""Reproduce the paper's Table 2 effect at CPU scale: the four off-policy
correction variants under policy lag, with and without replay.

  PYTHONPATH=src python examples/vtrace_ablation.py [--steps 400] [--lag 6]
"""
import argparse

import numpy as np

from repro.configs.base import ImpalaConfig
from repro.core.driver import run_training


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=400)
    p.add_argument("--lag", type=int, default=6)
    p.add_argument("--env", default="catch")
    args = p.parse_args()

    print(f"env={args.env} policy_lag={args.lag} steps={args.steps}")
    print(f"{'variant':<14s} {'no-replay':>10s} {'replay':>10s}")
    for mode in ("vtrace", "onestep_is", "eps", "none"):
        row = []
        for replay in (False, True):
            cfg = ImpalaConfig(
                num_actions=3, unroll_length=20, learning_rate=6e-4,
                entropy_cost=0.003, rmsprop_eps=0.01, policy_lag=args.lag,
                correction=mode, replay_fraction=0.5 if replay else 0.0,
                replay_capacity=256)
            tracker, _ = run_training(args.env, cfg, num_envs=32,
                                      steps=args.steps, seed=7)
            row.append(tracker.mean_return(200))
        print(f"{mode:<14s} {row[0]:>10.3f} {row[1]:>10.3f}")
    print("\nExpected qualitative ordering (paper Table 2): "
          "vtrace >= onestep_is > eps/none, gap widening with replay.")


if __name__ == "__main__":
    main()
