"""Multi-task IMPALA with Population Based Training (paper §5.3 + App. F):
a population of agents, each one-set-of-weights across a task suite, with
PBT exploit/explore on (entropy cost, learning rate, RMSProp eps) and the
mean capped human-normalised score as fitness.

  PYTHONPATH=src python examples/multitask_pbt.py [--pop 4] [--rounds 6]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ImpalaConfig
from repro.configs.registry import get_smoke_config
from repro.core import actor as actor_lib
from repro.core import learner as learner_lib
from repro.core.metrics import EpisodeTracker, capped_normalised_score
from repro.core.pbt import PBTController
from repro.core.queue import LagController
from repro.data.envs import make_env
from repro.models import backbone as bb
from repro.models import common

TASKS = ["catch", "bandit"]
REFS = {"catch": (-0.6, 1.0), "bandit": (0.25, 1.0)}


def build_member(arch, num_actions, hypers, seed):
    cfg = ImpalaConfig(num_actions=num_actions, unroll_length=16,
                       learning_rate=hypers["learning_rate"],
                       entropy_cost=hypers["entropy_cost"],
                       rmsprop_eps=hypers["rmsprop_eps"], policy_lag=1)
    train_step, opt = learner_lib.build_train_step(arch, cfg, num_actions)
    return cfg, jax.jit(train_step), opt


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--pop", type=int, default=4)
    p.add_argument("--rounds", type=int, default=6)
    p.add_argument("--steps-per-round", type=int, default=40)
    args = p.parse_args()

    envs = {t: make_env(t) for t in TASKS}
    num_actions = max(e.num_actions for e in envs.values())
    hw = (max(e.image_hw[0] for e in envs.values()),
          max(e.image_hw[1] for e in envs.values()), 3)
    # shared-frame wrapper
    from repro.data.multitask import padded_env
    envs = {t: padded_env(e, hw, num_actions) for t, e in envs.items()}
    arch = get_smoke_config("impala-shallow").replace(image_hw=hw)
    specs = bb.backbone_specs(arch, num_actions)

    pbt = PBTController(pop_size=args.pop, seed=0)
    weights = [common.init_params(specs, jax.random.key(i))
               for i in range(args.pop)]
    opt_states = [None] * args.pop

    for rnd in range(args.rounds):
        for i in range(args.pop):
            cfg, train_step, opt = build_member(arch, num_actions,
                                                pbt.members[i].hypers, i)
            if opt_states[i] is None:
                opt_states[i] = opt.init(weights[i])
            params = weights[i]
            scores = []
            for t, env in envs.items():
                init_fn, unroll = actor_lib.build_actor(env, arch, cfg, 8)
                carry = init_fn(jax.random.key(100 * rnd + i))
                lag = LagController(cfg.policy_lag, params)
                tracker = EpisodeTracker(8)
                for step in range(args.steps_per_round):
                    carry, traj = unroll(lag.actor_params(), carry)
                    tracker.update(np.asarray(traj["rewards"]),
                                   np.asarray(traj["done"]))
                    params, opt_states[i], _ = train_step(
                        params, opt_states[i], jnp.int32(step), traj)
                    lag.on_update(params)
                scores.append(tracker.mean_return(100))
            weights[i] = params
            fitness = capped_normalised_score(
                scores, [REFS[t][1] for t in TASKS],
                [REFS[t][0] for t in TASKS])
            pbt.report_fitness(i, fitness)
        # PBT evolution step
        for i in range(args.pop):
            new_h, copied = pbt.exploit_explore(i, rnd, weights)
            tag = " (copied)" if copied else ""
            print(f"round {rnd} member {i}: fitness="
                  f"{pbt.members[i].fitness:.3f} "
                  f"lr={new_h['learning_rate']:.2e} "
                  f"ent={new_h['entropy_cost']:.2e}{tag}")
    best = pbt.best()
    print(f"\nbest member {best}: fitness {pbt.members[best].fitness:.3f}")


if __name__ == "__main__":
    main()
