"""The learner consume-path overhaul: dynamic-batch collection keeps
oldest-first order under partial buckets, donation really retires the
old params/opt_state buffers while everything published stays live, and
the staged host stacking is bit-identical to the np.concatenate it
replaced (ping-pong included)."""
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ImpalaConfig
from repro.distributed import TrajectoryItem, TrajectoryQueue
from repro.distributed.runtime import (_buckets, _collect_batch,
                                       _HostStager, _stack)


def _item(i, b=2, t=3):
    rng = np.random.default_rng(i)
    data = {"x": rng.standard_normal((b, t)).astype(np.float32),
            "n": np.full((b,), i, np.int32)}
    return TrajectoryItem(data, param_version=i, actor_id=0,
                          produced_at=float(i))


# ---------------------------------------------------------------------------
# bucket collection / requeue ordering


def test_buckets_descending_powers_of_two():
    assert _buckets(1) == [1]
    assert _buckets(4) == [4, 2, 1]
    assert _buckets(6) == [4, 2, 1]     # non-pow2 max rounds down


def test_collect_batch_partial_bucket_keeps_oldest_first():
    """5 queued with max bucket 4: first batch = the 4 oldest, the 5th
    (popped during the greedy drain) goes back to the *front*; the next
    batch starts with it. No trajectory is reordered or lost."""
    q = TrajectoryQueue(capacity=8, policy="block")
    for i in range(5):
        q.put(_item(i))
    first = q.get_nowait()
    batch = _collect_batch(q, _buckets(4), first)
    assert [it.param_version for it in batch] == [0, 1, 2, 3]
    assert len(q) == 1
    nxt = q.get_nowait()
    assert nxt.param_version == 4


def test_collect_batch_trims_to_pow2_and_requeues_overflow_in_order():
    """3 queued with max bucket 4 -> batch of 2 (largest pow2 <= 3), the
    third requeued at the front in its original position."""
    q = TrajectoryQueue(capacity=8, policy="block")
    for i in range(3):
        q.put(_item(i))
    first = q.get_nowait()
    batch = _collect_batch(q, _buckets(4), first)
    assert [it.param_version for it in batch] == [0, 1]
    # the overflow is next, still ahead of anything newly produced
    q.put(_item(99))
    nxt = q.get_nowait()
    assert nxt.param_version == 2
    batch2 = _collect_batch(q, _buckets(4), nxt)
    assert [it.param_version for it in batch2] == [2, 99]


# ---------------------------------------------------------------------------
# donation safety


def test_donated_train_step_retires_inputs_and_snapshot_survives():
    """The exact discipline the async runtime relies on: after a donated
    call, the input params/opt_state buffers are dead (reuse raises),
    while a jitted pre-call copy — what the runtime publishes — stays
    fully usable. Skips if this backend ignores donation."""
    from repro.core import learner as learner_lib
    from repro.core.driver import small_arch
    from repro.data.envs import make_bandit
    from repro.models import backbone as bb
    from repro.models import common as pcommon

    env = make_bandit()
    arch = small_arch(env)
    icfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=4,
                        learning_rate=1e-3, rmsprop_eps=0.01)
    specs = bb.backbone_specs(arch, env.num_actions)
    params = pcommon.init_params(specs, jax.random.key(0))
    train_step, opt = learner_lib.build_train_step(arch, icfg,
                                                   env.num_actions)
    train_step = jax.jit(train_step, donate_argnums=(0, 1))
    opt_state = opt.init(params)
    snapshot = jax.jit(lambda t: jax.tree.map(jnp.copy, t))

    b, t, hw = 2, 4, env.image_hw
    rng = np.random.default_rng(0)
    batch = {
        "obs_image": rng.integers(0, 255, (b, t + 1) + hw).astype(np.uint8),
        "last_action": np.zeros((b, t + 1), np.int32),
        "last_reward": np.zeros((b, t + 1), np.float32),
        "done_in": np.zeros((b, t + 1), bool),
        "lstm_state": tuple(np.zeros((b, arch.lstm_width), np.float32)
                            for _ in range(2)),
        "actions": np.zeros((b, t), np.int32),
        "rewards": rng.standard_normal((b, t)).astype(np.float32),
        "discounts": np.full((b, t), 0.99, np.float32),
        "behaviour_logprob": np.full((b, t), -1.0, np.float32),
        "done": np.zeros((b, t), bool),
    }
    published = snapshot(params)
    old_leaf = jax.tree.leaves(params)[0]
    old_opt_leaf = jax.tree.leaves(opt_state)[0]
    new_params, new_opt, metrics = train_step(params, opt_state,
                                              jnp.int32(0), batch)
    jax.block_until_ready(new_params)
    if not old_leaf.is_deleted():
        pytest.skip("backend ignores donation; nothing to enforce")
    assert old_opt_leaf.is_deleted()
    # the donated originals must raise on reuse ...
    with pytest.raises(RuntimeError):
        jnp.sum(old_leaf).block_until_ready()
    # ... while the published snapshot and the new trees stay live
    jax.block_until_ready(jax.tree.map(jnp.sum, published))
    jax.block_until_ready(jax.tree.map(jnp.sum, new_params))
    assert np.isfinite(float(metrics["loss/total"]))
    # and a second update over the fresh trees still works (in-place
    # reuse did not corrupt the chain)
    p2, o2, m2 = train_step(new_params, new_opt, jnp.int32(1), batch)
    jax.block_until_ready(p2)
    assert np.isfinite(float(m2["loss/total"]))


@pytest.mark.timeout_s(300)
def test_async_runtime_donate_toggle_trains():
    """donate=False must remain a supported escape hatch, and both
    settings must produce a full run with live telemetry."""
    from repro.distributed import run_async_training

    icfg = ImpalaConfig(num_actions=3, unroll_length=8,
                        learning_rate=1e-3, entropy_cost=0.003,
                        rmsprop_eps=0.01)
    for donate in (True, False):
        tracker, metrics, tel = run_async_training(
            "bandit", icfg, num_envs=4, steps=4, num_actors=2,
            queue_capacity=4, queue_policy="block", max_batch_trajs=2,
            seed=1, donate=donate)
        assert tel["learner_updates"] == 4, donate
        assert tel["donate"] is donate
        assert np.isfinite(float(metrics["loss/total"])), donate


def test_param_mirror_upload_never_aliases_host_buffer():
    """The process-actor subscriber decodes every publish into one
    reused host mirror and uploads with jnp.array. The upload MUST be a
    guaranteed copy: jnp.asarray zero-copy aliases 64-byte-aligned host
    buffers on the CPU backend, and an aliased param leaf would be torn
    by the next publish's in-place decode while the unroll reads it.
    Probes on a deterministically 64-aligned view so the result doesn't
    depend on allocator luck."""
    raw = np.zeros(1024 + 16, np.float32)
    off = (-raw.ctypes.data) % 64 // raw.itemsize
    mirror_leaf = raw[off:off + 1024]
    params = jax.tree.map(jnp.array, {"w": mirror_leaf})
    jax.block_until_ready(params)
    mirror_leaf[:] = 7.0                    # the next publish's decode
    np.testing.assert_array_equal(np.asarray(params["w"]),
                                  np.zeros(1024, np.float32))


# ---------------------------------------------------------------------------
# staged host stacking


def _np_items(k, b=3, shapes=((4,), (2, 5)), dtypes=(np.float32, np.int32),
              seed=0):
    rng = np.random.default_rng(seed)
    items = []
    for i in range(k):
        data = {
            "a": rng.standard_normal((b,) + shapes[0]).astype(dtypes[0]),
            "nest": {"z": rng.integers(0, 9, (b,) + shapes[1])
                     .astype(dtypes[1])},
            "state": tuple(rng.standard_normal((b, 3)).astype(np.float32)
                           for _ in range(2)),
        }
        items.append(TrajectoryItem(data, i, 0, time.monotonic()))
    return items


def _concat_reference(items):
    return jax.tree.map(lambda *xs: np.concatenate(xs, axis=0),
                        *[it.data for it in items])


def test_staged_stack_matches_concatenate_reference():
    stager = _HostStager()
    items = _np_items(4)
    out = _stack(items, stager)
    ref = _concat_reference(items)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        assert isinstance(got, jax.Array)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_staged_stack_reuse_decision_matches_device_put_semantics():
    """The stager may only reuse staging buffers where device_put
    COPIES; on backends that zero-copy alias host memory (the CPU
    backend aliases 64-byte-aligned buffers) it must allocate fresh
    buffers per stack — an aliased batch has no completion event to
    wait on before a rewrite."""
    from repro.distributed.runtime import _device_put_copies

    stager = _HostStager()
    assert stager._reuse is _device_put_copies()
    _stack(_np_items(2, seed=1), stager)
    _stack(_np_items(2, seed=2), stager)
    if stager._reuse:
        # one (bucket, structure) slot, two ping-ponged buffer sets
        assert len(stager._slots) == 1
    else:
        assert not stager._slots       # fresh buffers every call


def test_staged_stack_sequence_does_not_corrupt_earlier_batches():
    """Three consecutive stacks of the same bucket: the first batch must
    keep its values after later stacks — whether the stager ping-pongs
    preallocated buffers (copying backends) or allocates fresh ones
    (aliasing backends)."""
    stager = _HostStager()
    a = _stack(_np_items(2, seed=1), stager)
    a_host = jax.tree.map(np.asarray, a)
    b = _stack(_np_items(2, seed=2), stager)
    c = _stack(_np_items(2, seed=3), stager)
    jax.block_until_ready((b, c))
    for got, want in zip(jax.tree.leaves(a), jax.tree.leaves(a_host)):
        np.testing.assert_array_equal(np.asarray(got), want)


def test_staged_stack_handles_readonly_views_and_bf16():
    """Serialized transports deliver read-only zero-copy views, and
    params/trajectories may carry bfloat16 — both must stage."""
    import ml_dtypes
    from repro.distributed import serde

    items = []
    for i in range(2):
        data = {"x": np.arange(6, dtype=np.float32).reshape(3, 2) + i,
                "h": (np.ones((3, 2)) * i).astype(ml_dtypes.bfloat16)}
        buf = serde.encode_item(TrajectoryItem(data, i, 0, 0.0))
        items.append(serde.decode_item(buf))    # read-only views
    assert not jax.tree.leaves(items[0].data)[0].flags.writeable
    stager = _HostStager()
    out = _stack(items, stager)
    ref = _concat_reference(items)
    for got, want in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(got), want)


def test_staged_stack_falls_back_on_ragged_batches():
    """Mismatched per-item shapes are not the hot path but must still
    stack correctly via the concatenate fallback."""
    stager = _HostStager()
    i1 = TrajectoryItem({"x": np.ones((2, 3), np.float32)}, 0, 0, 0.0)
    i2 = TrajectoryItem({"x": np.zeros((4, 3), np.float32)}, 1, 0, 0.0)
    out = _stack([i1, i2], stager)
    assert out["x"].shape == (6, 3)
    assert not stager._slots       # staging never engaged


def test_stack_single_item_passthrough_and_device_leaves():
    stager = _HostStager()
    i1 = TrajectoryItem({"x": np.ones((2, 3), np.float32)}, 0, 0, 0.0)
    assert _stack([i1], stager) is i1.data
    d1 = TrajectoryItem({"x": jnp.ones((2, 3))}, 0, 0, 0.0)
    d2 = TrajectoryItem({"x": jnp.zeros((2, 3))}, 1, 0, 0.0)
    out = _stack([d1, d2], stager)
    assert out["x"].shape == (4, 3)
    assert not stager._slots       # device leaves keep the jnp path
