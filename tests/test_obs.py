"""The flight recorder (repro.obs): metrics registry semantics, the
Prometheus /metrics + /healthz endpoint, trajectory lifecycle tracing
with cross-clock normalization, the JSONL sink and profile-window
parsing — plus one end-to-end async run with the whole stack on,
curled mid-run through the real HTTP server."""
import json
import re
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.obs import ObsConfig
from repro.obs.http import MetricsServer, health, render_prometheus
from repro.obs.metrics import Counter, Gauge, IntHistogram, Registry
from repro.obs.sink import JsonlSink, parse_profile_steps
from repro.obs.trace import (EXCHANGE_SPAN_NAMES, SPAN_NAMES,
                             TraceRecorder)


# ---------------------------------------------------------------------------
# Registry


def test_registry_create_or_get_identity():
    reg = Registry()
    c1 = reg.counter("q.pushed")
    c2 = reg.counter("q.pushed")
    assert c1 is c2
    c1.inc(3)
    c2.inc()
    assert reg.collect()["q.pushed"] == 4
    g = reg.gauge("q.size")
    g.set(7.5)
    h = reg.int_histogram("lag")
    h.observe(0, 2)
    h.counts[3] += 1              # hot paths write the Counter directly
    col = reg.collect()
    assert col["q.size"] == 7.5
    assert col["lag"] == {0: 2, 3: 1}
    # the collected histogram is a copy, not the live storage
    col["lag"][9] = 99
    assert 9 not in reg.collect()["lag"]


def test_registry_type_mismatch_raises():
    reg = Registry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.int_histogram("x")


def test_registry_producers_none_omitted_and_errors_captured():
    reg = Registry()
    reg.register_producer("queue", lambda: {"depth": 2})
    reg.register_producer("inference", lambda: None)
    def boom():
        raise RuntimeError("snapshot torn")
    reg.register_producer("exchange", boom)
    col = reg.collect()
    assert col["queue"] == {"depth": 2}
    assert "inference" not in col
    assert "snapshot torn" in col["exchange"]["error"]
    # re-registering replaces (components are rebuilt per run)
    reg.register_producer("queue", lambda: {"depth": 5})
    assert reg.collect()["queue"]["depth"] == 5


# ---------------------------------------------------------------------------
# Prometheus rendering + health


_PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"(,[a-zA-Z_][a-zA-Z0-9_]*='
    r'"[^"]*")*\})? -?[0-9]+(\.[0-9]+)?([eE][+-]?[0-9]+)?$')


def test_render_prometheus_names_buckets_and_learner_label():
    snap = {
        "frames_per_sec": 1234.5,
        "queue": {"mean_occupancy": 1.25, "dropped": 0,
                  "policy": "block"},          # str: skipped
        "lag": {"hist": {0: 10, 3: 2}, "mean": 0.5},
        "learners": {
            "learner_0": {"frames_per_sec": 600.0},
            "learner_1": {"frames_per_sec": 634.5},
        },
        "learner.lag_hist": {1: 4},            # producer-namespaced key
        "actor_mode": "unroll",                # str: skipped
        "donate": True,
    }
    text = render_prometheus(snap)
    lines = [ln for ln in text.splitlines() if ln]
    for ln in lines:
        assert _PROM_LINE.match(ln), ln
    assert "repro_frames_per_sec 1234.5" in lines
    assert 'repro_lag_hist{bucket="0"} 10' in lines
    assert 'repro_lag_hist{bucket="3"} 2' in lines
    # learners.learner_<k> collapses to a learner="k" label
    assert 'repro_frames_per_sec{learner="0"} 600' in lines
    assert 'repro_frames_per_sec{learner="1"} 634.5' in lines
    # dotted producer keys split like nesting
    assert 'repro_learner_lag_hist{bucket="1"} 4' in lines
    assert "repro_donate 1" in lines
    assert not any("actor_mode" in ln or "policy" in ln for ln in lines)


def test_health_ok_degraded_unhealthy():
    code, body = health({"queue": {"dropped": 0}, "lag": {"mean": 0.0}})
    assert (code, body["status"]) == (200, "ok")
    code, body = health({"queue": {"dropped": 3},
                         "socket": {"reconnects": 1}})
    assert (code, body["status"]) == (200, "degraded")
    assert any("dropped=3" in r for r in body["reasons"])
    code, body = health({"group": {"dead_learners": [2]},
                         "queue": {"dropped": 3}})
    assert (code, body["status"]) == (503, "unhealthy")
    code, body = health({"exchange": {"hub_gone": True}})
    assert code == 503
    code, body = health({"group": {"replicas_identical": False}})
    assert code == 503


def test_health_supervisor_tri_state():
    # a healthy supervised run: counters present, all quiet
    code, body = health({"supervisor": {
        "restarts": 0, "failovers": 0, "restart_in_flight": 0,
        "failover_in_flight": 0, "restarts_exhausted": []}})
    assert (code, body["status"]) == (200, "ok")
    # mid-respawn / mid-failover / solo: degraded, still serving 200
    for key in ("restart_in_flight", "failover_in_flight"):
        code, body = health({"supervisor": {key: 1}})
        assert (code, body["status"]) == (200, "degraded"), key
        assert any(key in r for r in body["reasons"])
    code, body = health({"exchange": {"degraded_solo": True}})
    assert (code, body["status"]) == (200, "degraded")
    # completed restarts are history, not a live condition
    code, body = health({"supervisor": {"restarts": 4, "failovers": 1}})
    assert (code, body["status"]) == (200, "ok")
    # an exhausted restart budget means a child is down for good: 503
    code, body = health({"supervisor": {
        "restarts": 5, "restarts_exhausted": ["actor-3"]}})
    assert (code, body["status"]) == (503, "unhealthy")
    assert any("actor-3" in r for r in body["reasons"])


# ---------------------------------------------------------------------------
# MetricsServer (real sockets, loopback)


def _get(addr, route):
    url = f"http://{addr[0]}:{addr[1]}{route}"
    with urllib.request.urlopen(url, timeout=5) as r:
        return r.status, r.read().decode("utf-8")


def test_metrics_server_routes():
    state = {"snap": {"frames_per_sec": 10.0, "queue": {"dropped": 0}}}
    srv = MetricsServer(lambda: state["snap"], port=0).start()
    try:
        code, text = _get(srv.address, "/metrics")
        assert code == 200 and "repro_frames_per_sec 10" in text
        code, text = _get(srv.address, "/healthz")
        assert code == 200 and json.loads(text)["status"] == "ok"
        code, text = _get(srv.address, "/telemetry")
        assert code == 200
        assert json.loads(text) == state["snap"]
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.address, "/nope")
        assert ei.value.code == 404
        # degraded flips the /healthz body but not the status code
        state["snap"] = {"queue": {"dropped": 9}}
        code, text = _get(srv.address, "/healthz")
        assert code == 200 and json.loads(text)["status"] == "degraded"
        # unhealthy is a real 503 (load balancers understand it)
        state["snap"] = {"exchange": {"hub_gone": True}}
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.address, "/healthz")
        assert ei.value.code == 503
        assert json.loads(ei.value.read().decode())["status"] == \
            "unhealthy"
    finally:
        srv.stop()


def test_metrics_server_snapshot_failure_is_500_not_crash():
    def boom():
        raise RuntimeError("mid-teardown")
    srv = MetricsServer(boom, port=0).start()
    try:
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.address, "/metrics")
        assert ei.value.code == 500
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# TraceRecorder


class _Item:
    def __init__(self, trace, actor_id=0, param_version=5):
        self.trace = trace
        self.actor_id = actor_id
        self.param_version = param_version


def _spans_by_name(events):
    return {e["name"]: e for e in events if e.get("ph") == "X"}


def test_trace_recorder_emits_all_seven_spans():
    rec = TraceRecorder()
    t = 100.0
    tr = {"u0": t, "u1": t + 1, "e0": t + 1.1, "e1": t + 1.2,
          "r": t + 1.3}
    rec.record_item(_Item(tr), dequeued=t + 1.5, collected=t + 1.6,
                    step0=t + 1.7, step1=t + 1.9, published=t + 2.0,
                    lag=2)
    spans = _spans_by_name(rec.chrome_events())
    assert set(spans) == set(SPAN_NAMES)
    assert rec.recorded == 1
    # spans tile the lifecycle: each starts where the previous ended
    assert spans["env_unroll"]["dur"] == pytest.approx(1e6)
    assert spans["transport"]["ts"] == pytest.approx((t + 1.2) * 1e6)
    assert spans["queue_wait"]["ts"] == pytest.approx((t + 1.3) * 1e6)
    assert spans["publish"]["dur"] == pytest.approx(0.1e6, rel=1e-3)
    assert spans["train_step"]["args"]["lag"] == 2
    # actor spans on the actor row, learner spans on the learner row
    assert spans["env_unroll"]["pid"] == 1000
    assert spans["train_step"]["pid"] == 1
    names = [e for e in rec.chrome_events() if e["ph"] == "M"]
    assert {e["args"]["name"] for e in names} == {"actor-0", "learner"}


def test_trace_recorder_cross_clock_normalization():
    """Actor stamps from a clock 1000s behind the learner's: the send
    (e1) must land at the learner's receive (r) and all actor spans
    must come out on the learner's clock."""
    rec = TraceRecorder()
    lr = 5000.0                       # learner clock
    ar = 4000.0                       # actor clock, 1000s behind
    tr = {"u0": ar, "u1": ar + 1, "e0": ar + 1, "e1": ar + 1.1, "r": lr}
    rec.record_item(_Item(tr), dequeued=lr + 0.2, collected=lr + 0.3,
                    step0=lr + 0.3, step1=lr + 0.4, published=lr + 0.45)
    spans = _spans_by_name(rec.chrome_events())
    # e1 shifted onto r: transport span is zero-length, not -1000s
    assert spans["transport"]["ts"] == pytest.approx(lr * 1e6)
    assert spans["transport"]["dur"] == 0.0
    # u0 was 1.1s before e1 on the actor's clock; shifted it sits 1.1s
    # before the learner-side receive
    assert spans["env_unroll"]["ts"] == pytest.approx((lr - 1.1) * 1e6)
    assert spans["env_unroll"]["dur"] == pytest.approx(1e6)


def test_trace_recorder_partial_stamps_and_bound():
    rec = TraceRecorder(max_trajectories=2)
    # no trace dict at all: ignored entirely
    rec.record_item(_Item(None), dequeued=1, collected=1, step0=1,
                    step1=1, published=1)
    assert rec.recorded == 0
    # only u-stamps (inproc transport, encode never ran): no exception,
    # missing stamps degrade to zero-length spans
    rec.record_item(_Item({"u0": 10.0, "u1": 10.5}), dequeued=10.6,
                    collected=10.7, step0=10.7, step1=10.8,
                    published=10.9)
    spans = _spans_by_name(rec.chrome_events())
    assert set(spans) == set(SPAN_NAMES)
    assert spans["serde_encode"]["dur"] == 0.0
    rec.record_item(_Item({"u0": 11.0, "u1": 11.5}), dequeued=11.6,
                    collected=11.7, step0=11.7, step1=11.8,
                    published=11.9)
    rec.record_item(_Item({"u0": 12.0, "u1": 12.5}), dequeued=12.6,
                    collected=12.7, step0=12.7, step1=12.8,
                    published=12.9)
    assert rec.recorded == 2 and rec.dropped == 1


def test_trace_recorder_exchange_round_spans():
    rec = TraceRecorder(max_trajectories=2)
    t = 50.0
    rec.record_exchange_round(3, enter=t, gathered=t + 0.2,
                              reduced=t + 0.25, done=t + 0.3)
    events = rec.chrome_events()
    spans = [e for e in events if e["ph"] == "X"]
    # three spans tiling the round, all on the exchange row
    assert [s["name"] for s in spans] == list(EXCHANGE_SPAN_NAMES)
    assert all(s["pid"] == 2 for s in spans)
    assert all(s["args"] == {"round": 3} for s in spans)
    assert spans[0]["ts"] == pytest.approx(t * 1e6)
    assert spans[0]["dur"] == pytest.approx(0.2e6)          # hub_wait
    assert spans[1]["ts"] == pytest.approx((t + 0.2) * 1e6)  # reduce
    assert spans[2]["dur"] == pytest.approx(0.05e6)         # broadcast
    rows = [e for e in events if e["ph"] == "M"]
    assert any(e["args"]["name"] == "exchange" for e in rows)
    # rounds share the trajectory budget: bounded, drops counted
    rec.record_exchange_round(4, enter=t, gathered=t, reduced=t, done=t)
    rec.record_exchange_round(5, enter=t, gathered=t, reduced=t, done=t)
    assert rec.recorded == 2 and rec.dropped == 1


def test_trace_export_loads_as_chrome_trace(tmp_path):
    rec = TraceRecorder()
    rec.record_item(_Item({"u0": 1.0, "u1": 2.0}), dequeued=2.1,
                    collected=2.2, step0=2.2, step1=2.3, published=2.4)
    path = tmp_path / "trace.json"
    assert rec.export(str(path)) == 1
    doc = json.loads(path.read_text())
    assert isinstance(doc["traceEvents"], list)
    assert {e["name"] for e in doc["traceEvents"]
            if e["ph"] == "X"} == set(SPAN_NAMES)


# ---------------------------------------------------------------------------
# serde carries the trace across the wire


def test_serde_roundtrips_trace_and_stamps_e1():
    from repro.distributed import serde

    traj = {"obs": np.zeros((3, 2), np.float32),
            "rewards": np.ones((3,), np.float32)}
    before = time.monotonic()
    item = serde.TrajectoryItem(traj, param_version=4, actor_id=1,
                                produced_at=123.0,
                                trace={"u0": 1.0, "u1": 2.0, "e0": 2.5})
    out = serde.decode_item(serde.encode_item(item))
    assert out.trace is not None
    assert out.trace["u0"] == 1.0 and out.trace["e0"] == 2.5
    # encode stamped e1 itself, after building the payload
    assert before <= out.trace["e1"] <= time.monotonic()
    # the sender's dict was not mutated
    assert "e1" not in item.trace
    # and a traceless item still round-trips with trace None
    plain = serde.TrajectoryItem(traj, 4, 1, 123.0)
    assert serde.decode_item(serde.encode_item(plain)).trace is None


# ---------------------------------------------------------------------------
# sink + profiling window


def test_jsonl_sink_writes_lines(tmp_path):
    path = tmp_path / "tel.jsonl"
    sink = JsonlSink(str(path), lambda: {"x": 1}, interval_s=0.05)
    sink.start()
    time.sleep(0.2)
    sink.stop()
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert sink.lines_written == len(lines) >= 2
    assert all(ln["telemetry"] == {"x": 1} and "t" in ln
               for ln in lines)


def test_parse_profile_steps():
    assert parse_profile_steps("3:10") == (3, 10)
    assert parse_profile_steps("0:0") == (0, 0)
    for bad in ("10", "5:2", "-1:4", "a:b"):
        with pytest.raises(ValueError):
            parse_profile_steps(bad)


# ---------------------------------------------------------------------------
# end to end: the whole stack on one async run


def test_async_run_with_full_observability(tmp_path):
    """One real async run with metrics server, trace sampling on every
    trajectory, and the JSONL sink — /metrics and /healthz are curled
    mid-run through the live server, the exported trace has all seven
    lifecycle spans, and telemetry gains the phase-timing section."""
    from repro.configs.base import ImpalaConfig
    from repro.distributed import run_async_training

    trace_path = tmp_path / "trace.json"
    sink_path = tmp_path / "tel.jsonl"
    obs = ObsConfig(metrics_port=0, trace_path=str(trace_path),
                    trace_every=1, sink_path=str(sink_path),
                    sink_interval_s=0.1)
    mid = {}

    def on_update(step, params, metrics, snapshot_fn):
        if step == 3 and obs.bound_address is not None:
            code, text = _get(obs.bound_address, "/metrics")
            mid["metrics"] = (code, text)
            mid["healthz"] = _get(obs.bound_address, "/healthz")

    icfg = ImpalaConfig(num_actions=3, unroll_length=8,
                        learning_rate=1e-3, entropy_cost=0.003,
                        rmsprop_eps=0.01)
    tracker, metrics, tel = run_async_training(
        "bandit", icfg, num_envs=4, steps=6, num_actors=1,
        queue_capacity=4, queue_policy="block", max_batch_trajs=2,
        seed=0, on_update=on_update, obs=obs)
    assert tel["learner_updates"] == 6

    # the mid-run curl saw live counters in valid Prometheus format
    code, text = mid["metrics"]
    assert code == 200
    lines = [ln for ln in text.splitlines() if ln]
    assert lines and all(_PROM_LINE.match(ln) for ln in lines)
    assert any(ln.startswith("repro_learner_updates ") for ln in lines)
    assert any(ln.startswith("repro_frames_per_sec ") for ln in lines)
    code, text = mid["healthz"]
    assert code == 200 and json.loads(text)["status"] in ("ok",
                                                          "degraded")

    # phase timing rode along (obs enables it) without breaking the
    # pinned telemetry keys the other tests rely on
    ph = tel["phases"]
    assert ph["updates_timed"] == 6
    assert set(ph["total_s"]) == {"collect", "host_stage", "device_put",
                                  "step", "publish"}
    assert all(v >= 0.0 for v in ph["total_s"].values())

    # exported trace: all seven spans, parseable as chrome trace JSON
    doc = json.loads(trace_path.read_text())
    spans = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert spans == set(SPAN_NAMES)

    # sink left a time series behind
    sl = [json.loads(ln) for ln in sink_path.read_text().splitlines()]
    assert sl and sl[-1]["telemetry"]["learner_updates"] == 6
