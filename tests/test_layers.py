"""Layer-level unit tests: attention (dense vs chunked, windows, GQA),
SSD chunked-vs-naive, RG-LRU scan, MoE dispatch, norms/rope."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig
from repro.configs.registry import get_smoke_config
from repro.models import attention as attn
from repro.models import common, moe as moe_lib, ssm as ssm_lib
from repro.models.rglru import chunked_diag_scan


# ---------------------------------------------------------------------------
# attention


def _qkv(key, b, t, h, kh, d):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, t, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kh, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("h,kh", [(4, 4), (4, 2), (4, 1)])
@pytest.mark.parametrize("window", [0, 7])
def test_chunked_matches_dense(h, kh, window):
    b, t, d = 2, 50, 16
    q, k, v = _qkv(jax.random.key(h * 10 + window), b, t, h, kh, d)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    mask = pos[:, None, None, None, :] <= pos[:, None, None, :, None]
    if window:
        mask &= pos[:, None, None, None, :] > (pos[:, None, None, :, None]
                                               - window)
    dense = attn._dense_attention(q, k, v, mask, d ** -0.5)
    chunked = attn._chunked_causal_attention(q, k, v, pos, pos, d ** -0.5,
                                             window=window,
                                             q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 60), st.integers(0, 20), st.integers(0, 2 ** 31 - 1))
def test_chunked_property(t, window, seed):
    b, h, d = 1, 2, 8
    q, k, v = _qkv(jax.random.key(seed), b, t, h, h, d)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    mask = pos[:, None, None, None, :] <= pos[:, None, None, :, None]
    if window:
        mask &= pos[:, None, None, None, :] > (pos[:, None, None, :, None]
                                               - window)
    dense = attn._dense_attention(q, k, v, mask, d ** -0.5)
    chunked = attn._chunked_causal_attention(q, k, v, pos, pos, d ** -0.5,
                                             window=window,
                                             q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=1e-4, rtol=1e-4)


def test_ring_buffer_decode_matches_full_context():
    """Sliding-window ring-buffer decode == full attention limited to the
    window, beyond one window of context."""
    cfg = get_smoke_config("mistral_nemo_12b").replace(sliding_window=8)
    specs = attn.attention_specs(cfg)
    params = common.init_params(specs, jax.random.key(0))
    b, t = 1, 24
    x = jax.random.normal(jax.random.key(1), (b, t, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    full, _ = attn.apply_attention(params, x, pos, cfg, causal=True,
                                   window=8, mode="train")
    # decode step-by-step with ring cache of size 8
    spec = attn.CacheSpec(8, cfg.num_kv_heads, cfg.resolved_head_dim)
    cache = attn.init_cache_arrays(b, spec, jnp.bfloat16)
    outs = []
    for i in range(t):
        y, cache = attn.apply_attention(
            params, x[:, i:i + 1], pos[:, i:i + 1], cfg, causal=True,
            window=8, mode="decode", cache=cache,
            cache_index=jnp.int32(i))
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full, np.float32)[:, 8:],
                               np.asarray(dec, np.float32)[:, 8:],
                               atol=3e-2)


# ---------------------------------------------------------------------------
# SSD (mamba2)


def _naive_ssd(x, dt, a_log, b, c, d_skip):
    """O(T^2)-free literal recurrence for the oracle."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    a = -np.exp(np.asarray(a_log, np.float64))
    state = np.zeros((bsz, h, p, n))
    ys = np.zeros((bsz, t, h, p))
    xn, dtn, bn, cn = map(lambda z: np.asarray(z, np.float64),
                          (x, dt, b, c))
    for s in range(t):
        decay = np.exp(dtn[:, s] * a)[:, :, None, None]
        state = decay * state + np.einsum(
            "bhp,bn->bhpn", xn[:, s] * dtn[:, s][:, :, None], bn[:, s])
        ys[:, s] = np.einsum("bhpn,bn->bhp", state, cn[:, s])
    ys += np.asarray(d_skip)[None, None, :, None] * xn
    return ys, state


@pytest.mark.parametrize("t,chunk", [(8, 4), (17, 4), (32, 8), (5, 16)])
def test_ssd_chunked_matches_naive(t, chunk):
    bsz, h, p, n = 2, 3, 4, 5
    ks = jax.random.split(jax.random.key(t * 10 + chunk), 5)
    x = jax.random.normal(ks[0], (bsz, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, t, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.3
    b = jax.random.normal(ks[3], (bsz, t, n))
    c = jax.random.normal(ks[4], (bsz, t, n))
    d_skip = jnp.ones((h,)) * 0.5
    y, state = ssm_lib.ssd_chunked(x, dt, a_log, b, c, d_skip, chunk)
    y_ref, state_ref = _naive_ssd(x, dt, a_log, b, c, d_skip)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), state_ref, atol=1e-3,
                               rtol=1e-3)


def test_ssd_step_matches_chunked():
    bsz, t, h, p, n = 1, 6, 2, 4, 3
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (bsz, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, t, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.3
    b = jax.random.normal(ks[3], (bsz, t, n))
    c = jax.random.normal(ks[4], (bsz, t, n))
    d_skip = jnp.zeros((h,))
    y_full, _ = ssm_lib.ssd_chunked(x, dt, a_log, b, c, d_skip, chunk=4)
    state = jnp.zeros((bsz, h, p, n))
    for s in range(t):
        y_s, state = ssm_lib.ssd_step(state, x[:, s], dt[:, s], a_log,
                                      b[:, s], c[:, s], d_skip)
        np.testing.assert_allclose(np.asarray(y_s),
                                   np.asarray(y_full[:, s]), atol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU diag scan


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 70), st.integers(1, 16), st.sampled_from([4, 16, 256]),
       st.integers(0, 2 ** 31 - 1))
def test_chunked_diag_scan_property(t, w, chunk, seed):
    ks = jax.random.split(jax.random.key(seed), 3)
    a = jax.random.uniform(ks[0], (1, t, w), minval=0.0, maxval=1.0)
    b = jax.random.normal(ks[1], (1, t, w))
    h0 = jax.random.normal(ks[2], (1, w))
    h, hf = chunked_diag_scan(a, b, h0, chunk=chunk)
    # naive
    cur = np.asarray(h0, np.float64)
    for s in range(t):
        cur = np.asarray(a[:, s]) * cur + np.asarray(b[:, s])
        np.testing.assert_allclose(np.asarray(h[:, s]), cur, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), cur, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE


def test_moe_matches_dense_full_compute_with_big_capacity():
    """With capacity >= tokens*k, capacity dispatch must equal the literal
    'every token through its top-k experts' computation."""
    cfg = get_smoke_config("olmoe_1b_7b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    specs = moe_lib.moe_specs(cfg)
    params = common.init_params(specs, jax.random.key(0))
    b, t = 2, 10
    x = jax.random.normal(jax.random.key(1), (b, t, cfg.d_model),
                          jnp.float32)
    y, aux = moe_lib.apply_moe(params, x.astype(jnp.bfloat16), cfg)

    gates, idx, _ = moe_lib.route(params, x, cfg)
    xd = x.astype(jnp.bfloat16)
    up = params["up"]["kernel"].astype(jnp.bfloat16)
    gate_w = params["gate"]["kernel"].astype(jnp.bfloat16)
    down = params["down"]["kernel"].astype(jnp.bfloat16)
    # literal per-token loop
    y_ref = np.zeros((b, t, cfg.d_model), np.float32)
    for bi in range(b):
        for ti in range(t):
            for ki in range(cfg.moe.num_experts_per_tok):
                e = int(idx[bi, ti, ki])
                h = np.asarray(jax.nn.silu(xd[bi, ti] @ gate_w[e]) *
                               (xd[bi, ti] @ up[e]), np.float32)
                o = np.asarray(h.astype(np.float32) @
                               np.asarray(down[e], np.float32))
                y_ref[bi, ti] += float(gates[bi, ti, ki]) * o
    np.testing.assert_allclose(np.asarray(y, np.float32), y_ref,
                               atol=0.15, rtol=0.15)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg = get_smoke_config("olmoe_1b_7b")
    cfg = cfg.replace(moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.25))
    specs = moe_lib.moe_specs(cfg)
    params = common.init_params(specs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y, _ = moe_lib.apply_moe(params, x, cfg)
    assert y.shape == x.shape
    assert not np.isnan(np.asarray(y, np.float32)).any()


# ---------------------------------------------------------------------------
# norms / rope


def test_rmsnorm_unit_scale():
    p = {"scale": jnp.ones((8,))}
    x = jax.random.normal(jax.random.key(0), (4, 8)) * 5
    y = common.rmsnorm(p, x)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.key(0), (1, 6, 2, 8))
    pos = jnp.arange(6)[None]
    y = common.rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # dot products depend only on relative offsets
    q = common.rope(jnp.broadcast_to(x[:, :1], x.shape), pos)
    k = common.rope(jnp.broadcast_to(x[:, 1:2], x.shape), pos)
    d1 = np.einsum("bshd,bshd->bsh", np.asarray(q[:, :3]),
                   np.asarray(k[:, :3]))
    d2 = np.einsum("bshd,bshd->bsh", np.asarray(q[:, 2:5]),
                   np.asarray(k[:, 2:5]))
    np.testing.assert_allclose(d1, d2, rtol=1e-4)
