"""Distribution correctness: the sharded train_step computes the same
function as the single-device one, across sharding profiles and the
mixed-precision variant. Runs in a subprocess with 8 fake devices."""
import json
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import ImpalaConfig
    from repro.configs.registry import get_smoke_config
    from repro.core import learner as learner_lib
    from repro.models import backbone as bb, common
    from repro.sharding.rules import Rules, use_rules

    cfg = get_smoke_config("stablelm_1_6b").replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=512)
    icfg = ImpalaConfig(num_actions=9, learning_rate=1e-3)
    specs = bb.backbone_specs(cfg, 9)
    params = common.init_params(specs, jax.random.key(0))
    key = jax.random.key(1)
    B, T = 8, 12
    batch = {
        "obs_token": jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size),
        "actions": jax.random.randint(key, (B, T), 0, 9),
        "rewards": jax.random.normal(key, (B, T)),
        "discounts": jnp.full((B, T), 0.99),
        "behaviour_logprob": -jnp.ones((B, T)),
    }

    losses = {}
    # single device reference
    ts, opt = learner_lib.build_train_step(cfg, icfg, 9)
    p1, _, m = jax.jit(ts)(params, opt.init(params), jnp.int32(0), batch)
    losses["single"] = float(m["loss/total"])
    ref_leaf = np.asarray(jax.tree.leaves(p1)[0], np.float32)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    for profile in [None, {"embed": ("data", "model"), "heads": None,
                           "kv_heads": None, "ff": None, "vocab": None,
                           "batch": ("data", "model")}]:
        rules = Rules(mesh, profile)
        def step(p, o, s, b):
            with use_rules(rules):
                return ts(p, o, s, b)
        psh = common.param_shardings(specs, rules)
        osh = {"ms": psh}
        bsh = jax.tree.map(
            lambda x: NamedSharding(mesh, rules.spec(
                ("batch",) + (None,) * (x.ndim - 1), x.shape)), batch)
        with mesh:
            f = jax.jit(step, in_shardings=(psh, osh, NamedSharding(mesh, P()), bsh))
            p2, _, m2 = f(params, opt.init(params), jnp.int32(0), batch)
        tag = "baseline_tp" if profile is None else "fsdp"
        losses[tag] = float(m2["loss/total"])
        leaf = np.asarray(jax.tree.leaves(p2)[0], np.float32)
        losses[tag + "_param_err"] = float(np.abs(leaf - ref_leaf).max())
    print(json.dumps(losses))
""")


def test_sharded_train_step_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    ref = out["single"]
    assert abs(out["baseline_tp"] - ref) < 1e-2 * max(abs(ref), 1), out
    assert abs(out["fsdp"] - ref) < 1e-2 * max(abs(ref), 1), out
    assert out["baseline_tp_param_err"] < 1e-3, out
    assert out["fsdp_param_err"] < 1e-3, out
