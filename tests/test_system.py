"""End-to-end behaviour: the full IMPALA pipeline (actors -> queue ->
learner with V-trace + replay + lag + checkpoint) trains a policy on CPU,
and the V-trace correction demonstrably beats no-correction under policy
lag (the paper's Table 2 effect, miniature)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ImpalaConfig
from repro.configs.registry import get_smoke_config
from repro.core import actor as actor_lib
from repro.core import learner as learner_lib
from repro.core.metrics import EpisodeTracker
from repro.core.queue import LagController, TrajectoryQueue
from repro.core.replay import ReplayBuffer, mix_batches
from repro.checkpoint import checkpoint as ckpt
from repro.data.envs import make_bandit, make_catch
from repro.models import backbone as bb
from repro.models import common


def _train(env, arch, icfg, num_envs, steps, seed=0, replay=False):
    specs = bb.backbone_specs(arch, env.num_actions)
    params = common.init_params(specs, jax.random.key(seed))
    init_fn, unroll = actor_lib.build_actor(env, arch, icfg, num_envs)
    train_step, opt = learner_lib.build_train_step(arch, icfg,
                                                   env.num_actions)
    train_step = jax.jit(train_step)
    opt_state = opt.init(params)
    carry = init_fn(jax.random.key(seed + 1))
    lag = LagController(icfg.policy_lag, params)
    queue = TrajectoryQueue(capacity=4)
    buf = ReplayBuffer(icfg.replay_capacity, seed=seed)
    tracker = EpisodeTracker(num_envs)
    metrics = {}
    for step in range(steps):
        carry, traj = unroll(lag.actor_params(), carry)
        queue.put(traj)
        tracker.update(np.asarray(traj["rewards"]),
                       np.asarray(traj["done"]))
        batch = queue.get()
        if replay:
            buf.add_batch(batch)
            rep = buf.sample(num_envs)
            batch = mix_batches(batch, rep, icfg.replay_fraction)
        params, opt_state, metrics = train_step(params, opt_state,
                                                jnp.int32(step), batch)
        lag.on_update(params)
    return params, tracker, metrics


def test_full_pipeline_learns_bandit():
    env = make_bandit()
    arch = get_smoke_config("impala_shallow").replace(image_hw=(4, 4, 3))
    icfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=16,
                        learning_rate=1e-3, entropy_cost=0.005,
                        rmsprop_eps=0.01, policy_lag=1)
    _, tracker, metrics = _train(env, arch, icfg, num_envs=32, steps=150)
    assert np.isfinite(float(metrics["loss/total"]))
    final = tracker.mean_return(200)
    assert final > 0.6, f"bandit should approach 1.0, got {final}"


def test_replay_pipeline_runs():
    env = make_catch()
    arch = get_smoke_config("impala_shallow").replace(image_hw=(10, 5, 3))
    icfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=10,
                        learning_rate=5e-4, policy_lag=2,
                        replay_fraction=0.5, replay_capacity=64)
    _, tracker, metrics = _train(env, arch, icfg, num_envs=8, steps=12,
                                 replay=True)
    assert np.isfinite(float(metrics["loss/total"]))


def test_vtrace_beats_no_correction_under_lag():
    """Miniature Table 2: with strong policy lag, V-trace reaches a higher
    return than 'none' on the bandit."""
    env = make_bandit()
    arch = get_smoke_config("impala_shallow").replace(image_hw=(4, 4, 3))
    finals = {}
    for mode in ("vtrace", "none"):
        icfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=16,
                            learning_rate=2e-3, entropy_cost=0.003,
                            rmsprop_eps=0.01, policy_lag=8,
                            correction=mode)
        _, tracker, _ = _train(env, arch, icfg, num_envs=32, steps=120,
                               seed=3)
        finals[mode] = tracker.mean_return(200)
    # V-trace should do at least as well; 'none' is often unstable here.
    assert finals["vtrace"] >= finals["none"] - 0.05, finals


def test_checkpoint_resume_preserves_training(tmp_path):
    env = make_bandit()
    arch = get_smoke_config("impala_shallow").replace(image_hw=(4, 4, 3))
    icfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=8,
                        learning_rate=1e-3)
    params, _, _ = _train(env, arch, icfg, num_envs=8, steps=5)
    ckpt.save(str(tmp_path), 5, params)
    restored, step = ckpt.restore(str(tmp_path), params)
    assert step == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_token_backbone_actor_pipeline():
    """A (tiny) transformer policy acts via the decode/cache path and
    trains via the full-trajectory path — the exact IMPALA actor/learner
    split the assigned architectures use."""
    env = make_bandit()
    arch = get_smoke_config("stablelm_1_6b").replace(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
        vocab_size=max(env.vocab_size, 32))
    icfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=8,
                        learning_rate=1e-3, rmsprop_eps=0.01)
    _, tracker, metrics = _train(env, arch, icfg, num_envs=8, steps=10)
    assert np.isfinite(float(metrics["loss/total"]))
    assert len(tracker.completed) > 0


@pytest.mark.parametrize("arch_name", ["impala_shallow", "stablelm_1_6b"])
def test_actor_learner_logprob_alignment(arch_name):
    """With zero policy lag, the learner's recomputed log pi(a_t|x_t) must
    equal the behaviour log-prob the actor shipped — i.e. log_rhos == 0.
    Any off-by-one in trajectory packing would silently corrupt every
    importance weight; this pins the alignment end-to-end."""
    from repro.core import vtrace as vt

    env = make_bandit()
    if arch_name == "impala_shallow":
        arch = get_smoke_config(arch_name).replace(image_hw=(4, 4, 3))
    else:
        arch = get_smoke_config(arch_name).replace(
            num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
            d_ff=128, vocab_size=max(env.vocab_size, 32))
    icfg = ImpalaConfig(num_actions=env.num_actions, unroll_length=10)
    specs = bb.backbone_specs(arch, env.num_actions)
    params = common.init_params(specs, jax.random.key(0))
    init_fn, unroll = actor_lib.build_actor(env, arch, icfg, num_envs=4)
    carry = init_fn(jax.random.key(1))
    carry, traj = unroll(params, carry)  # warm-up unroll
    carry, traj = unroll(params, carry)

    logits, values, _ = learner_lib.forward_trajectory(params, traj, arch,
                                                       env.num_actions)
    learner_logp = vt.action_log_probs(logits[:, :-1], traj["actions"])
    log_rhos = np.asarray(learner_logp) - np.asarray(
        traj["behaviour_logprob"])
    assert np.abs(log_rhos).max() < 5e-2, np.abs(log_rhos).max()
