"""V-trace correctness: Eq. (1) literal form vs scan vs Pallas kernel,
the paper's analytical properties (on-policy reduction, Remark 1
recursion, truncation semantics), and Theorem 1's fixed point on a
tabular MDP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import vtrace as vt
from repro.core.corrections import nstep_returns


def _inputs(key, b, t, scale=0.5):
    ks = jax.random.split(key, 5)
    log_rhos = jax.random.normal(ks[0], (b, t)) * scale
    discounts = jnp.where(jax.random.uniform(ks[1], (b, t)) < 0.1, 0.0, 0.9)
    rewards = jax.random.normal(ks[2], (b, t))
    values = jax.random.normal(ks[3], (b, t))
    boot = jax.random.normal(ks[4], (b,))
    return log_rhos, discounts, rewards, values, boot


@pytest.mark.parametrize("b,t", [(1, 1), (2, 7), (4, 50)])
def test_scan_matches_reference(b, t):
    args = _inputs(jax.random.key(b * 100 + t), b, t)
    a = vt.vtrace_scan(*args)
    r = vt.vtrace_reference(*args)
    np.testing.assert_allclose(a.vs, r.vs, atol=1e-5)
    np.testing.assert_allclose(a.pg_advantages, r.pg_advantages, atol=1e-5)


def test_pallas_kernel_matches_scan():
    args = _inputs(jax.random.key(0), 8, 64)
    a = vt.vtrace_scan(*args)
    k = vt.vtrace(*args, impl="pallas")
    np.testing.assert_allclose(a.vs, k.vs, atol=1e-5)
    np.testing.assert_allclose(a.pg_advantages, k.pg_advantages, atol=1e-5)


def test_on_policy_reduces_to_nstep_bellman():
    """Paper Eq. (2): pi == mu and c_bar >= 1 => n-step Bellman target."""
    _, discounts, rewards, values, boot = _inputs(jax.random.key(1), 3, 20)
    zeros = jnp.zeros_like(rewards)
    ret = vt.vtrace_scan(zeros, discounts, rewards, values, boot)
    g = nstep_returns(discounts, rewards, values, boot)
    np.testing.assert_allclose(ret.vs, g, atol=1e-5)


def test_recursion_identity():
    """Remark 1: v_s = V(x_s) + delta_s V + gamma c_s (v_{s+1} - V(x_{s+1}))."""
    log_rhos, discounts, rewards, values, boot = _inputs(
        jax.random.key(2), 2, 15)
    ret = vt.vtrace_scan(log_rhos, discounts, rewards, values, boot)
    rho = jnp.minimum(1.0, jnp.exp(log_rhos))
    c = jnp.minimum(1.0, jnp.exp(log_rhos))
    v_tp1 = jnp.concatenate([values[:, 1:], boot[:, None]], 1)
    vs_tp1 = jnp.concatenate([ret.vs[:, 1:], boot[:, None]], 1)
    delta = rho * (rewards + discounts * v_tp1 - values)
    rhs = values + delta + discounts * c * (vs_tp1 - v_tp1)
    np.testing.assert_allclose(ret.vs, rhs, atol=1e-5)


def test_cbar_does_not_change_fixed_point_direction():
    """c_bar affects contraction speed only; with on-policy data any c_bar
    gives the same target (all ratios are 1)."""
    _, discounts, rewards, values, boot = _inputs(jax.random.key(3), 2, 12)
    zeros = jnp.zeros_like(rewards)
    a = vt.vtrace_scan(zeros, discounts, rewards, values, boot, c_bar=1.0)
    b = vt.vtrace_scan(zeros, discounts, rewards, values, boot, c_bar=0.5)
    # with log_rhos = 0 the c weights are min(c_bar, 1) -> c_bar matters;
    # but rho=1 keeps delta the same; check c_bar=1 vs larger is identical
    c = vt.vtrace_scan(zeros, discounts, rewards, values, boot, c_bar=4.0)
    np.testing.assert_allclose(a.vs, c.vs, atol=1e-6)
    assert not np.allclose(a.vs, b.vs)  # truncation below 1 does bite


def test_rho_zero_gives_behaviour_value():
    """rho_bar -> 0: deltas vanish, v_s -> V(x_s) (evaluates mu ~ V itself)."""
    log_rhos, discounts, rewards, values, boot = _inputs(
        jax.random.key(4), 2, 10)
    ret = vt.vtrace_scan(log_rhos, discounts, rewards, values, boot,
                         rho_bar=1e-9, c_bar=1e-9)
    np.testing.assert_allclose(ret.vs, values, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 30), st.integers(0, 2 ** 31 - 1))
def test_property_scan_equals_reference(b, t, seed):
    args = _inputs(jax.random.key(seed), b, t)
    a = vt.vtrace_scan(*args)
    r = vt.vtrace_reference(*args)
    np.testing.assert_allclose(a.vs, r.vs, atol=1e-4, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.floats(0.2, 3.0), st.integers(0, 2 ** 31 - 1))
def test_property_lambda_zero_is_one_step(lam, seed):
    """lambda = 0 cuts all traces: v_s = V + rho_s(r + g V(x_{s+1}) - V)."""
    log_rhos, discounts, rewards, values, boot = _inputs(
        jax.random.key(seed), 2, 9, scale=lam / 3)
    ret = vt.vtrace_scan(log_rhos, discounts, rewards, values, boot,
                         lambda_=0.0)
    rho = jnp.minimum(1.0, jnp.exp(log_rhos))
    v_tp1 = jnp.concatenate([values[:, 1:], boot[:, None]], 1)
    expect = values + rho * (rewards + discounts * v_tp1 - values)
    np.testing.assert_allclose(ret.vs, expect, atol=1e-5)


# ---------------------------------------------------------------------------
# Theorem 1: the fixed point is V^{pi_rho_bar}


def _mdp(seed=0, ns=4, na=3, gamma=0.9):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.ones(ns), size=(ns, na))      # (s,a,s')
    r = rng.normal(size=(ns, na))
    pi = rng.dirichlet(np.ones(na) * 2, size=ns)
    mu = rng.dirichlet(np.ones(na) * 2, size=ns)
    return p, r, pi, mu, gamma


def _value_of(policy, p, r, gamma):
    ns = p.shape[0]
    pp = np.einsum("sa,sat->st", policy, p)
    rr = np.einsum("sa,sa->s", policy, r)
    return np.linalg.solve(np.eye(ns) - gamma * pp, rr)


def test_tabular_fixed_point_is_pi_rho_bar():
    """Online V-trace updates (Theorem 2) converge to V^{pi_rho_bar} (Eq. 3)."""
    p, r, pi, mu, gamma = _mdp()
    ns, na = r.shape
    rho_bar = 1.0
    num = np.minimum(rho_bar * mu, pi)
    pi_rho = num / num.sum(-1, keepdims=True)
    v_star = _value_of(pi_rho, p, r, gamma)

    rng = np.random.default_rng(1)
    v = np.zeros(ns)
    n = 8  # n-step updates
    s = 0
    for it in range(80000):
        lr = 0.2 / (1.0 + it / 4000.0)  # Robbins-Monro-ish anneal
        # generate an n-step trajectory from mu
        states, actions, rewards = [], [], []
        st_ = s
        for _ in range(n + 1):
            a = rng.choice(na, p=mu[st_])
            states.append(st_)
            actions.append(a)
            rewards.append(r[st_, a])
            st_ = rng.choice(ns, p=p[states[-1], a])
        states.append(st_)
        # apply the n-step V-trace update at the first state
        acc = 0.0
        coef = 1.0
        for k in range(n):
            sk, ak = states[k], actions[k]
            rho = min(rho_bar, pi[sk, ak] / mu[sk, ak])
            c = min(1.0, pi[sk, ak] / mu[sk, ak])
            delta = rho * (rewards[k] + gamma * v[states[k + 1]] - v[sk])
            acc += coef * delta
            coef *= gamma * c
        v[states[0]] += lr * acc
        s = states[1]
    np.testing.assert_allclose(v, v_star, atol=0.15)
