"""Environment invariants (hypothesis property tests on the data substrate)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.data.envs import ENV_MAKERS, make_env


@pytest.mark.parametrize("name", sorted(ENV_MAKERS))
def test_env_basic_contract(name):
    env = make_env(name)
    key = jax.random.key(0)
    s = env.reset(key)
    ts = env.observe(s)
    assert ts.obs_token.dtype == jnp.int32
    assert 0 <= int(ts.obs_token) < env.vocab_size
    assert ts.obs_image.dtype == jnp.uint8
    assert ts.obs_image.shape == env.image_hw
    for i in range(50):
        key, k1, k2 = jax.random.split(key, 3)
        a = jax.random.randint(k1, (), 0, env.num_actions)
        s, ts = env.step(s, a, k2)
        assert 0 <= int(ts.obs_token) < env.vocab_size, name
        assert np.isfinite(float(ts.reward))


@pytest.mark.parametrize("name", sorted(ENV_MAKERS))
def test_env_jit_and_vmap(name):
    env = make_env(name)
    keys = jax.random.split(jax.random.key(0), 4)
    states = jax.vmap(env.reset)(keys)
    step = jax.jit(jax.vmap(env.step))
    actions = jnp.zeros((4,), jnp.int32)
    states, ts = step(states, actions, jax.random.split(jax.random.key(1), 4))
    assert ts.reward.shape == (4,)
    assert ts.obs_token.shape == (4,)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(sorted(ENV_MAKERS)), st.integers(0, 2 ** 31 - 1))
def test_env_episodes_terminate(name, seed):
    """Every env must emit done=True within a bounded horizon (auto-reset)."""
    env = make_env(name)
    key = jax.random.key(seed)
    s = env.reset(key)
    seen_done = False
    for i in range(200):
        key, k1, k2 = jax.random.split(key, 3)
        a = jax.random.randint(k1, (), 0, env.num_actions)
        s, ts = env.step(s, a, k2)
        if bool(ts.done):
            seen_done = True
            break
    assert seen_done, f"{name} never terminated in 200 steps"


def test_catch_reward_semantics():
    env = make_env("catch")
    key = jax.random.key(0)
    s = env.reset(key)
    total = 0.0
    for i in range(100):
        key, k = jax.random.split(key)
        s, ts = env.step(s, jnp.int32(1), k)  # stay
        total += float(ts.reward)
        if bool(ts.done):
            assert float(ts.reward) in (-1.0, 1.0)


def test_bandit_optimal_action_pays():
    env = make_env("bandit")
    key = jax.random.key(0)
    s = env.reset(key)
    for i in range(20):
        key, k = jax.random.split(key)
        ctx = int(s.ctx)
        s, ts = env.step(s, jnp.int32(ctx % env.num_actions), k)
        assert float(ts.reward) == 1.0
