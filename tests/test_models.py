"""Per-architecture smoke tests (deliverable f): every assigned arch as a
reduced variant runs one forward + one train step on CPU with shape checks
and no NaNs; prefill->decode cache consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ImpalaConfig
from repro.configs.registry import (ASSIGNED, get_smoke_config, get_config,
                                    list_configs)
from repro.core import learner as learner_lib
from repro.models import backbone as bb
from repro.models import common

A = 9
B, T = 2, 12


def _batch_for(cfg, key, t=T):
    toks = jax.random.randint(key, (B, t + 1), 0, cfg.vocab_size)
    batch = {
        "obs_token": toks,
        "actions": jax.random.randint(key, (B, t), 0, A),
        "rewards": jax.random.normal(key, (B, t)),
        "discounts": jnp.full((B, t), 0.99),
        "behaviour_logprob": -jnp.ones((B, t)),
    }
    if cfg.family == "audio":
        batch["enc_embed"] = jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["image_embed"] = jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.d_model)) * 0.1
    return batch


def _cnn_batch(cfg, key, t=T):
    h, w, c = cfg.image_hw
    return {
        "obs_image": jax.random.randint(key, (B, t + 1, h, w, c), 0, 255,
                                        dtype=jnp.int32).astype(jnp.uint8),
        "last_action": jax.random.randint(key, (B, t + 1), 0, A),
        "last_reward": jax.random.normal(key, (B, t + 1)),
        "done_in": jnp.zeros((B, t + 1), bool),
        "actions": jax.random.randint(key, (B, t), 0, A),
        "rewards": jax.random.normal(key, (B, t)),
        "discounts": jnp.full((B, t), 0.99),
        "behaviour_logprob": -jnp.ones((B, t)),
    }


@pytest.mark.parametrize("name", list_configs())
def test_smoke_forward_and_train_step(name):
    cfg = get_smoke_config(name)
    if cfg.family != "impala_cnn":  # conv nets are tiny already (<=300K)
        assert cfg.num_layers <= 5 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    specs = bb.backbone_specs(cfg, A)
    params = common.init_params(specs, jax.random.key(0))
    key = jax.random.key(1)
    batch = (_cnn_batch(cfg, key) if cfg.family == "impala_cnn"
             else _batch_for(cfg, key))

    icfg = ImpalaConfig(num_actions=A, learning_rate=1e-3)
    train_step, opt = learner_lib.build_train_step(cfg, icfg, A)
    opt_state = opt.init(params)
    new_params, new_opt, metrics = jax.jit(train_step)(
        params, opt_state, jnp.int32(0), batch)

    logits, values, _ = learner_lib.forward_trajectory(params, batch, cfg, A)
    assert logits.shape == (B, T + 1, A)
    assert values.shape == (B, T + 1)
    assert not np.isnan(np.asarray(logits)).any()
    assert not np.isnan(np.asarray(values)).any()
    assert np.isfinite(float(metrics["loss/total"]))
    # params actually changed
    diff = jax.tree.reduce(
        lambda acc, x: acc + float(jnp.sum(jnp.abs(x))),
        jax.tree.map(lambda a, b_: (a.astype(jnp.float32) -
                                    b_.astype(jnp.float32)),
                     params, new_params), 0.0)
    assert diff > 0


@pytest.mark.parametrize("name", [n for n in ASSIGNED])
def test_prefill_decode_consistency(name):
    cfg = get_smoke_config(name)
    specs = bb.backbone_specs(cfg, A)
    params = common.init_params(specs, jax.random.key(0))
    key = jax.random.key(2)
    batch = _batch_for(cfg, key)
    toks = batch["obs_token"]
    full = bb.apply_train(params, {"tokens": toks,
                                   **{k: batch[k] for k in
                                      ("enc_embed", "image_embed")
                                      if k in batch}}, cfg, A)
    pre_in = {"tokens": toks[:, :T]}
    for k in ("enc_embed", "image_embed"):
        if k in batch:
            pre_in[k] = batch[k]
    pre = bb.apply_prefill(params, pre_in, cfg, A)
    np.testing.assert_allclose(np.asarray(pre.policy_logits[:, 0]),
                               np.asarray(full.policy_logits[:, T - 1]),
                               atol=5e-3)
    dec = bb.apply_decode(params, toks[:, T:T + 1], pre.cache,
                          jnp.int32(T), cfg, A)
    np.testing.assert_allclose(np.asarray(dec.policy_logits[:, 0]),
                               np.asarray(full.policy_logits[:, T]),
                               atol=3e-2)


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
    }
    for name, (l, d, h, kv, ff, v) in expect.items():
        c = get_config(name)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (l, d, h, kv, ff, v), name
        assert c.source, name
    assert get_config("granite-moe-1b-a400m").moe.num_experts == 32
    assert get_config("granite-moe-1b-a400m").moe.num_experts_per_tok == 8
    assert get_config("olmoe-1b-7b").moe.num_experts == 64
    assert get_config("mamba2-1.3b").ssm.state_dim == 128
    assert get_config("recurrentgemma-2b").rglru.pattern == (
        "recurrent", "recurrent", "attention")


def test_scan_vs_unrolled_equivalence():
    """scan_layers=False (dry-run mode) computes the same function."""
    cfg = get_smoke_config("stablelm-1.6b")
    specs = bb.backbone_specs(cfg, A)
    params = common.init_params(specs, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    a = bb.apply_train(params, {"tokens": toks}, cfg, A)
    b_ = bb.apply_train(params, {"tokens": toks},
                        cfg.replace(scan_layers=False), A)
    np.testing.assert_allclose(np.asarray(a.policy_logits),
                               np.asarray(b_.policy_logits), atol=5e-4)
