"""Optional-hypothesis shim.

``hypothesis`` is a dev-only dependency (pinned in requirements-dev.txt,
installed in CI). When it is absent the property tests must *skip* — not
break collection of the whole module, which would also take the plain
pytest tests in the same file down with them.

Usage in test modules:

    from hypothesis_compat import given, settings, st

With hypothesis installed these are the real objects and every property
test runs; without it ``@given(...)`` turns the test into a clean skip.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: any attribute is a
        callable returning None (strategies are only built at decoration
        time and never drawn from, since the test body is replaced)."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def _wrap(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(pip install -r requirements-dev.txt)")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return _wrap

    def settings(*_args, **_kwargs):
        def _wrap(fn):
            return fn
        return _wrap
