"""Suite-wide guard rails.

* The multiprocessing start method is pinned to ``spawn`` so the suite
  behaves identically on linux (default fork) and macos (default spawn),
  and so no test accidentally depends on fork inheriting jax state —
  forking a process with a live XLA runtime is undefined behaviour.

* Every test runs under a wall-clock watchdog (SIGALRM timer in the main
  thread): a hung multiprocess transport test fails fast with a
  TimeoutError instead of wedging the whole CI workflow until the job
  timeout. Override per test with ``@pytest.mark.timeout_s(N)``; the
  default comes from ``REPRO_TEST_TIMEOUT`` (seconds, 0 disables).
"""
import multiprocessing
import os
import signal

import pytest

DEFAULT_TIMEOUT_S = float(os.environ.get("REPRO_TEST_TIMEOUT", "600"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "timeout_s(seconds): per-test wall-clock cap enforced by the "
        "conftest SIGALRM watchdog (default REPRO_TEST_TIMEOUT)")
    try:
        multiprocessing.set_start_method("spawn")
    except RuntimeError:
        pass  # already set for this interpreter — keep whatever it is


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout_s")
    seconds = float(marker.args[0]) if marker else DEFAULT_TIMEOUT_S
    if seconds <= 0 or not hasattr(signal, "SIGALRM"):
        yield
        return

    def _on_timeout(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded the {seconds:.0f}s watchdog "
            f"(mark with @pytest.mark.timeout_s to adjust)")

    old_handler = signal.signal(signal.SIGALRM, _on_timeout)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old_handler)
