"""Core RL machinery: corrections, losses, replay, queue/lag, PBT, optim,
checkpoint, metrics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ImpalaConfig
from repro.core import corrections, losses, vtrace as vt
from repro.core.pbt import PBTController
from repro.core.queue import LagController, TrajectoryQueue
from repro.core.replay import ReplayBuffer, mix_batches
from repro.core.metrics import EpisodeTracker, capped_normalised_score
from repro.checkpoint import checkpoint as ckpt
from repro.optim import optimizer as opt_lib


def _batch(key, b=3, t=11, a=5):
    ks = jax.random.split(key, 6)
    return {
        "actions": jax.random.randint(ks[0], (b, t), 0, a),
        "rewards": jax.random.normal(ks[1], (b, t)),
        "discounts": jnp.full((b, t), 0.95),
        "behaviour_logprob": -jnp.abs(jax.random.normal(ks[2], (b, t))),
        "bootstrap_value": jax.random.normal(ks[3], (b,)),
    }, jax.random.normal(ks[4], (b, t, a)), jax.random.normal(ks[5], (b, t))


@pytest.mark.parametrize("mode", ["vtrace", "onestep_is", "eps", "none"])
def test_correction_modes_shapes(mode):
    cfg = ImpalaConfig(correction=mode)
    batch, logits, values = _batch(jax.random.key(0))
    vs, adv = corrections.compute_correction(
        cfg, batch["behaviour_logprob"], logits, batch["actions"],
        batch["discounts"], batch["rewards"], values,
        batch["bootstrap_value"])
    assert vs.shape == values.shape and adv.shape == values.shape
    assert np.isfinite(np.asarray(vs)).all()


def test_onpolicy_all_modes_agree_on_value_target():
    """With pi == mu, every mode's value target is the n-step return."""
    batch, logits, values = _batch(jax.random.key(1))
    # make behaviour logprob equal target logprob
    blp = vt.action_log_probs(logits, batch["actions"])
    batch["behaviour_logprob"] = blp
    targets = []
    for mode in ["vtrace", "onestep_is", "none"]:
        cfg = ImpalaConfig(correction=mode)
        vs, _ = corrections.compute_correction(
            cfg, blp, logits, batch["actions"], batch["discounts"],
            batch["rewards"], values, batch["bootstrap_value"])
        targets.append(np.asarray(vs))
    np.testing.assert_allclose(targets[0], targets[1], atol=1e-5)
    np.testing.assert_allclose(targets[0], targets[2], atol=1e-5)


def test_impala_loss_finite_and_entropy_sign():
    cfg = ImpalaConfig(entropy_cost=0.01)
    batch, logits, values = _batch(jax.random.key(2))
    total, metrics = losses.impala_loss(cfg, logits, values, batch)
    assert np.isfinite(float(total))
    # entropy_loss = sum p log p <= 0
    assert float(metrics["loss/entropy"]) <= 0.0


def test_reward_clip_modes():
    r = jnp.array([-10.0, -0.5, 0.0, 0.5, 10.0])
    np.testing.assert_allclose(losses.reward_clip(r, "abs_one"),
                               [-1, -0.5, 0, 0.5, 1])
    soft = np.asarray(losses.reward_clip(r, "soft_asymmetric"))
    assert soft[0] == pytest.approx(0.3 * np.tanh(-10.0), abs=1e-6)
    assert soft[-1] == pytest.approx(5.0 * np.tanh(10.0), abs=1e-6)
    assert (soft >= -0.3).all() and (soft <= 5.0).all()


def test_policy_gradient_direction():
    """Gradient step should raise log-prob of positively-advantaged action."""
    logits = jnp.zeros((1, 1, 3))
    actions = jnp.array([[1]])
    adv = jnp.array([[2.0]])

    def loss(lg):
        return losses.policy_gradient_loss(lg, actions, adv)

    g = jax.grad(loss)(logits)
    assert float(g[0, 0, 1]) < 0  # descending raises logit of action 1


# ---------------------------------------------------------------------------
# replay / queue / lag


def test_replay_fifo_and_sample():
    # the sample-stream identity is now an explicit (seed, learner_id) —
    # the old no-arg default_rng(0) fallback is deliberately gone
    buf = ReplayBuffer(capacity=8, seed=0)
    for i in range(6):
        buf.add_batch({"x": jnp.full((2, 3), i)})
    assert len(buf) == 8
    s = buf.sample(4)
    assert s["x"].shape == (4, 3)
    # FIFO: oldest (i=0) entries were overwritten
    vals = set()
    for i in range(20):
        vals.update(np.asarray(buf.sample(8)["x"][:, 0]).tolist())
    assert 0.0 not in vals


def test_mix_batches_fraction():
    online = {"x": jnp.zeros((8, 2))}
    rep = {"x": jnp.ones((8, 2))}
    mixed = mix_batches(online, rep, 0.5)
    assert float(mixed["x"].sum()) == 8.0  # 4 rows of ones


def test_queue_put_reports_drop_before_eviction():
    q = TrajectoryQueue(capacity=2)
    assert q.put("a") and q.put("b")        # ring always accepts
    assert q.dropped == 0
    assert q.put("c")                       # full: "a" evicted, counted
    assert q.dropped == 1 and q.pushed == 3
    assert q.get() == "b" and q.get() == "c" and q.get() is None


def test_queue_and_lag():
    q = TrajectoryQueue(capacity=2)
    q.put(1), q.put(2), q.put(3)
    assert q.dropped == 1 and q.get() == 2
    lag = LagController(2, "p0")
    lag.on_update("p1")
    lag.on_update("p2")
    assert lag.actor_params() == "p0"
    lag.on_update("p3")
    assert lag.actor_params() == "p1"
    lag0 = LagController(0, "a")
    lag0.on_update("b")
    assert lag0.actor_params() == "b"


# ---------------------------------------------------------------------------
# PBT (Appendix F)


def test_pbt_exploit_copies_better_member():
    c = PBTController(pop_size=2, seed=0, threshold=0.05)
    c.report_fitness(0, 0.1)
    c.report_fitness(1, 0.9)
    weights = ["w0", "w1"]
    hyp_before = dict(c.members[0].hypers)
    copied_any = False
    for _ in range(10):
        h, copied = c.exploit_explore(0, step=100, weights=weights)
        copied_any |= copied
    assert copied_any and weights[0] == "w1"
    assert c.members[0].copied_from == 1
    del hyp_before


def test_pbt_burn_in_blocks_exploit():
    c = PBTController(pop_size=2, seed=0, burn_in_steps=1000)
    c.report_fitness(0, 0.0)
    c.report_fitness(1, 1.0)
    weights = ["w0", "w1"]
    _, copied = c.exploit_explore(0, step=10, weights=weights)
    assert not copied and weights[0] == "w0"


def test_pbt_explore_perturbs_by_factor():
    c = PBTController(pop_size=1, seed=0)
    h0 = dict(c.members[0].hypers)
    for _ in range(50):
        c.exploit_explore(0, step=0, weights=["w"])
    h1 = c.members[0].hypers
    for k in h0:
        ratio = np.log(h1[k] / h0[k]) / np.log(1.2)
        assert abs(ratio - round(ratio)) < 1e-6  # power of 1.2 exactly


# ---------------------------------------------------------------------------
# optimizer


def test_rmsprop_matches_manual():
    opt = opt_lib.rmsprop(decay=0.9, eps=0.1)
    params = {"w": jnp.array([1.0, 2.0])}
    state = opt.init(params)
    g = {"w": jnp.array([0.5, -1.0])}
    upd, state = opt.update(g, state, params, jnp.float32(0.1))
    ms = 0.1 * np.asarray(g["w"]) ** 2
    expect = -0.1 * np.asarray(g["w"]) / np.sqrt(ms + 0.1)
    np.testing.assert_allclose(np.asarray(upd["w"]), expect, rtol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3, "b": jnp.ones((4,)) * 4}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert total == pytest.approx(1.0, rel=1e-5)


def test_linear_schedule():
    fn = opt_lib.linear_schedule(1.0, 0.0, 100)
    assert float(fn(jnp.int32(0))) == 1.0
    assert float(fn(jnp.int32(50))) == pytest.approx(0.5)
    assert float(fn(jnp.int32(200))) == 0.0


# ---------------------------------------------------------------------------
# checkpoint


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, tree, extra={"note": "x"})
    like = jax.tree.map(np.zeros_like, jax.tree.map(np.asarray, tree))
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert ckpt.latest_step(str(tmp_path)) == 7
    del like


# ---------------------------------------------------------------------------
# metrics


def test_capped_normalised_score_matches_table_b1():
    """IMPALA row of Table B.1: 49.4% mean capped normalised."""
    assert capped_normalised_score([100], [100], [0]) == 1.0
    assert capped_normalised_score([250], [100], [0]) == 1.0  # capped
    assert capped_normalised_score([50], [100], [0]) == 0.5
    assert capped_normalised_score([5.8, 26.9], [10.0, 54.0],
                                   [0.1, 4.1]) == pytest.approx(
        (min(1.0, 5.7 / 9.9) + min(1.0, 22.8 / 49.9)) / 2)


def test_episode_tracker():
    tr = EpisodeTracker(2)
    tr.update(np.array([[1.0, 1.0], [0.5, 0.0]]),
              np.array([[False, True], [False, False]]))
    assert tr.completed == [2.0]
    tr.update(np.array([[0.0], [0.5]]), np.array([[False], [True]]))
    assert tr.completed == [2.0, 1.0]


def test_pg_q_estimate_variants_appendix_e3():
    """Appendix E.3: q_s from v_{s+1} (default) vs from V(x_{s+1}).
    On-policy with a perfect value function both coincide; off-policy
    they differ (the default carries rollout information)."""
    batch, logits, values = _batch(jax.random.key(5))
    base = ImpalaConfig(correction="vtrace")
    e3 = ImpalaConfig(correction="vtrace", pg_q_estimate="baseline_v")
    vs_a, adv_a = corrections.compute_correction(
        base, batch["behaviour_logprob"], logits, batch["actions"],
        batch["discounts"], batch["rewards"], values,
        batch["bootstrap_value"])
    vs_b, adv_b = corrections.compute_correction(
        e3, batch["behaviour_logprob"], logits, batch["actions"],
        batch["discounts"], batch["rewards"], values,
        batch["bootstrap_value"])
    np.testing.assert_allclose(np.asarray(vs_a), np.asarray(vs_b))
    assert not np.allclose(np.asarray(adv_a), np.asarray(adv_b))
    # last step: v_{T} == bootstrap == V(x_T) -> advantages agree there
    np.testing.assert_allclose(np.asarray(adv_a[:, -1]),
                               np.asarray(adv_b[:, -1]), atol=1e-5)


def test_mixed_precision_step_matches_f32():
    from repro.configs.registry import get_smoke_config
    from repro.core import learner as learner_lib
    from repro.models import backbone as bb
    from repro.models import common as pc

    cfg = get_smoke_config("stablelm_1_6b")
    icfg = ImpalaConfig(num_actions=9, learning_rate=1e-3)
    specs = bb.backbone_specs(cfg, 9)
    p32 = pc.init_params(specs, jax.random.key(0))
    key = jax.random.key(1)
    b, t = 2, 12
    batch = {"obs_token": jax.random.randint(key, (b, t + 1), 0,
                                             cfg.vocab_size),
             "actions": jax.random.randint(key, (b, t), 0, 9),
             "rewards": jax.random.normal(key, (b, t)),
             "discounts": jnp.full((b, t), 0.99),
             "behaviour_logprob": -jnp.ones((b, t))}
    ts32, opt = learner_lib.build_train_step(cfg, icfg, 9)
    _, _, m32 = jax.jit(ts32)(p32, opt.init(p32), jnp.int32(0), batch)
    tsmp, opt2 = learner_lib.build_train_step(cfg, icfg, 9,
                                              mixed_precision=True)
    p16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16)
                       if jnp.issubdtype(x.dtype, jnp.floating) else x, p32)
    os_mp = {"opt": opt2.init(p32), "master": p32}
    p16b, os2, mmp = jax.jit(tsmp)(p16, os_mp, jnp.int32(0), batch)
    assert jax.tree.leaves(p16b)[0].dtype == jnp.bfloat16
    assert jax.tree.leaves(os2["master"])[0].dtype == jnp.float32
    assert abs(float(m32["loss/total"]) - float(mmp["loss/total"])) < 0.05
